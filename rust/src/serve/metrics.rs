//! Serving observability: lock-cheap counters and histograms behind the
//! `STATS` protocol verb and the periodic stderr heartbeat.
//!
//! Everything here is relaxed atomics over
//! [`Histogram`](crate::coordinator::metrics::Histogram) — recording a
//! request costs a handful of uncontended `fetch_add`s, so the metrics
//! layer never shows up in a latency profile.  Snapshots
//! ([`ServeMetrics::render`]) read the same atomics without stopping
//! writers, which is why every figure is "as of roughly now" rather than
//! a consistent cut — exactly what a dashboard needs and no more.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::coordinator::metrics::Histogram;

/// The live serving counters one [`super::server::Server`] owns.
#[derive(Debug)]
pub struct ServeMetrics {
    started: Instant,
    /// Completed requests (any verb that touched the index).
    requests: AtomicU64,
    /// Completed PREDICT requests.
    predicts: AtomicU64,
    /// Completed SEARCH requests.
    searches: AtomicU64,
    /// Completed EXTEND requests (index mutations).
    extends: AtomicU64,
    /// Rows appended to the index by EXTEND requests.
    extended_rows: AtomicU64,
    /// Requests answered with a typed ERROR frame (degraded rows,
    /// malformed frames, worker panics).
    degraded: AtomicU64,
    /// Requests currently between arrival and response (gauge).
    in_flight: AtomicU64,
    /// Connections accepted since start.
    connections: AtomicU64,
    /// Per-request latency, microseconds (arrival → response written).
    pub latency_us: Histogram,
    /// Executed batch sizes (1 = a query that rode alone).
    pub batch_size: Histogram,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            predicts: AtomicU64::new(0),
            searches: AtomicU64::new(0),
            extends: AtomicU64::new(0),
            extended_rows: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            latency_us: Histogram::new(),
            batch_size: Histogram::new(),
        }
    }

    /// A query entered the front door.  The returned guard decrements
    /// the `in_flight` gauge when dropped — including by panic
    /// unwinding, so a handler that dies mid-request cannot inflate the
    /// gauge permanently.
    #[inline]
    pub fn begin(&self) -> InFlight<'_> {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        InFlight { metrics: self }
    }

    /// A query's response hit the socket; `kind` is `"predict"` or
    /// `"search"`, `ok` is whether it carried a result (vs. ERROR).
    /// (The `in_flight` gauge is decremented by the [`InFlight`] guard
    /// from [`ServeMetrics::begin`], not here.)
    pub fn finish(&self, kind: RequestKind, ok: bool, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match kind {
            RequestKind::Predict => self.predicts.fetch_add(1, Ordering::Relaxed),
            RequestKind::Search => self.searches.fetch_add(1, Ordering::Relaxed),
            RequestKind::Extend => self.extends.fetch_add(1, Ordering::Relaxed),
        };
        if !ok {
            self.degraded.fetch_add(1, Ordering::Relaxed);
        }
        self.latency_us.record(latency_us);
    }

    /// Count a typed failure that never reached the index (malformed
    /// frame, dimension mismatch).
    #[inline]
    pub fn degraded_only(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count rows appended by a completed EXTEND request.
    #[inline]
    pub fn extended_rows(&self, rows: u64) {
        self.extended_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// Count an accepted connection.
    #[inline]
    pub fn connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an executed batch's size.
    #[inline]
    pub fn batch(&self, size: usize) {
        self.batch_size.record(size as u64);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn degraded(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Render the `STATS` text: one `key=value` per line, parseable with
    /// [`super::proto::stats_value`].  `cache` is the aggregated chunk
    /// -cache ledger of the disk-backed shards, if any.
    pub fn render(&self, cache: Option<(u64, u64)>) -> String {
        let uptime = self.uptime_s();
        let requests = self.requests();
        let mut out = String::new();
        let mut line = |k: &str, v: String| {
            out.push_str(k);
            out.push('=');
            out.push_str(&v);
            out.push('\n');
        };
        line("uptime_s", format!("{uptime:.3}"));
        line("connections", self.connections.load(Ordering::Relaxed).to_string());
        line("requests", requests.to_string());
        line("predicts", self.predicts.load(Ordering::Relaxed).to_string());
        line("searches", self.searches.load(Ordering::Relaxed).to_string());
        line("extends", self.extends.load(Ordering::Relaxed).to_string());
        line("extended_rows", self.extended_rows.load(Ordering::Relaxed).to_string());
        line("degraded", self.degraded().to_string());
        line("in_flight", self.in_flight().to_string());
        line("qps", format!("{:.2}", if uptime > 0.0 { requests as f64 / uptime } else { 0.0 }));
        let pct = |p: f64| {
            let v = self.latency_us.percentile(p);
            if v.is_nan() { "0".to_string() } else { format!("{v:.1}") }
        };
        line("lat_p50_us", pct(0.50));
        line("lat_p95_us", pct(0.95));
        line("lat_p99_us", pct(0.99));
        let mean = self.latency_us.mean();
        line("lat_mean_us", if mean.is_nan() { "0".into() } else { format!("{mean:.1}") });
        line("lat_max_us", self.latency_us.max().to_string());
        line("batches", self.batch_size.count().to_string());
        let bmean = self.batch_size.mean();
        line("batch_mean", if bmean.is_nan() { "0".into() } else { format!("{bmean:.2}") });
        line("batch_max", self.batch_size.max().to_string());
        if let Some((hits, misses)) = cache {
            let total = hits + misses;
            line("cache_hits", hits.to_string());
            line("cache_misses", misses.to_string());
            line(
                "cache_hit_rate",
                format!("{:.4}", if total > 0 { hits as f64 / total as f64 } else { 0.0 }),
            );
        }
        out
    }

    /// One-line summary for the periodic stderr heartbeat.
    pub fn heartbeat_line(&self, cache: Option<(u64, u64)>) -> String {
        let uptime = self.uptime_s();
        let requests = self.requests();
        let qps = if uptime > 0.0 { requests as f64 / uptime } else { 0.0 };
        let p50 = self.latency_us.percentile(0.50);
        let p99 = self.latency_us.percentile(0.99);
        let mut s = format!(
            "[gkm-serve] up {uptime:.0}s req {requests} qps {qps:.1} \
             p50 {:.0}us p99 {:.0}us in-flight {} degraded {}",
            if p50.is_nan() { 0.0 } else { p50 },
            if p99.is_nan() { 0.0 } else { p99 },
            self.in_flight(),
            self.degraded(),
        );
        if let Some((h, m)) = cache {
            let total = h + m;
            let rate = if total > 0 { h as f64 / total as f64 } else { 0.0 };
            s.push_str(&format!(" cache {:.1}%", rate * 100.0));
        }
        s
    }
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        ServeMetrics::new()
    }
}

/// RAII in-flight marker from [`ServeMetrics::begin`]: the gauge is
/// decremented on drop, so it stays accurate on every exit path —
/// normal completion *and* a panic unwinding out of the handler.
#[must_use = "dropping immediately would record an empty in-flight window"]
pub struct InFlight<'a> {
    metrics: &'a ServeMetrics,
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Which serving verb a completed request was (for per-verb counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    Predict,
    Search,
    Extend,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::proto::stats_value;

    #[test]
    fn render_reports_counts_and_percentiles() {
        let m = ServeMetrics::new();
        for i in 0..50u64 {
            let guard = m.begin();
            m.finish(RequestKind::Search, true, 100 + i);
            drop(guard);
        }
        let guard = m.begin();
        m.finish(RequestKind::Predict, false, 10_000);
        drop(guard);
        m.batch(8);
        m.batch(1);
        let guard = m.begin();
        m.finish(RequestKind::Extend, true, 5_000);
        drop(guard);
        m.extended_rows(64);
        let s = m.render(Some((90, 10)));
        assert_eq!(stats_value(&s, "requests"), Some(52.0));
        assert_eq!(stats_value(&s, "searches"), Some(50.0));
        assert_eq!(stats_value(&s, "predicts"), Some(1.0));
        assert_eq!(stats_value(&s, "extends"), Some(1.0));
        assert_eq!(stats_value(&s, "extended_rows"), Some(64.0));
        assert_eq!(stats_value(&s, "degraded"), Some(1.0));
        assert_eq!(stats_value(&s, "in_flight"), Some(0.0));
        assert_eq!(stats_value(&s, "batches"), Some(2.0));
        assert_eq!(stats_value(&s, "cache_hit_rate"), Some(0.9));
        let p50 = stats_value(&s, "lat_p50_us").unwrap();
        assert!(p50 > 0.0, "p50 must be nonzero after recording: {s}");
        let p99 = stats_value(&s, "lat_p99_us").unwrap();
        assert!(p99 >= p50);
        assert!(stats_value(&s, "qps").unwrap() >= 0.0);
        assert!(!m.heartbeat_line(Some((90, 10))).is_empty());
    }

    #[test]
    fn in_flight_gauge_survives_a_panicking_handler() {
        let m = ServeMetrics::new();
        {
            let _live = m.begin();
            assert_eq!(m.in_flight(), 1);
        }
        assert_eq!(m.in_flight(), 0, "guard drop without finish must decrement");
        // the panic path: the guard unwinds with the handler
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _live = m.begin();
            panic!("handler died mid-request");
        }));
        assert!(r.is_err());
        assert_eq!(m.in_flight(), 0, "a panicking handler must not leak the gauge");
    }

    #[test]
    fn empty_metrics_render_zeros_not_nans() {
        let m = ServeMetrics::new();
        let s = m.render(None);
        assert_eq!(stats_value(&s, "requests"), Some(0.0));
        assert_eq!(stats_value(&s, "lat_p50_us"), Some(0.0));
        assert_eq!(stats_value(&s, "batch_mean"), Some(0.0));
        assert_eq!(stats_value(&s, "cache_hits"), None, "no cache section without a ledger");
    }
}
