//! The latency-bounded micro-batcher: the front door that turns many
//! concurrent single-query connections into the batched kernel calls
//! ([`FittedModel::search_batch`](crate::model::FittedModel::search_batch))
//! the engine is actually fast at.
//!
//! ## Shape
//!
//! Connection workers [`Batcher::submit`] one query each and block; a
//! single dispatcher thread collects whatever arrives within a window
//! (`batch_window`, counted from the *first* queued query so an idle
//! server adds no latency floor) or until `max_batch` queries are
//! waiting, executes the whole batch with one closure call, and
//! fulfills every submitter.  Parallelism is *inside* the batch — the
//! exec closure fans the batch across the model's worker pool — so one
//! dispatcher never becomes the bottleneck it would be if it executed
//! queries one at a time.
//!
//! ## Fault containment
//!
//! The exec closure runs under `catch_unwind`.  A panicking batch (or a
//! closure returning the wrong number of results — a bug, but not one
//! worth deadlocking submitters over) resolves every submitter with
//! `on_panic(message)` instead of hanging them, and the dispatcher
//! lives on to serve the next batch.  Per-query faults never reach this
//! guard: the serving exec uses the degraded `try_*` kernels, which
//! report them as per-query typed errors.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Slot<R> {
    result: Mutex<Option<R>>,
    ready: Condvar,
}

impl<R> Slot<R> {
    fn new() -> Arc<Slot<R>> {
        Arc::new(Slot { result: Mutex::new(None), ready: Condvar::new() })
    }

    fn fulfill(&self, r: R) {
        *self.result.lock().unwrap() = Some(r);
        self.ready.notify_one();
    }

    fn wait(&self) -> R {
        let mut guard = self.result.lock().unwrap();
        loop {
            if let Some(r) = guard.take() {
                return r;
            }
            guard = self.ready.wait(guard).unwrap();
        }
    }
}

struct Job<Q, R> {
    query: Q,
    slot: Arc<Slot<R>>,
}

struct State<Q, R> {
    jobs: VecDeque<Job<Q, R>>,
    closed: bool,
}

struct Shared<Q, R> {
    state: Mutex<State<Q, R>>,
    arrived: Condvar,
}

/// A latency-bounded micro-batcher over an arbitrary batch executor.
///
/// Generic so the batching/panic logic is testable without a model:
/// the server instantiates it with `Q` = decoded request, `R` = wire
/// response.
pub struct Batcher<Q: Send + 'static, R: Send + 'static> {
    shared: Arc<Shared<Q, R>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl<Q: Send + 'static, R: Send + 'static> Batcher<Q, R> {
    /// Start the dispatcher.
    ///
    /// * `window` — how long the dispatcher waits after the first query
    ///   queues before executing an undersized batch (`0` = dispatch
    ///   immediately with whatever has accumulated).
    /// * `max_batch` — execute as soon as this many queries wait.
    /// * `exec` — runs each batch; must return exactly one result per
    ///   query, in order.
    /// * `on_panic` — builds the per-query result when `exec` panics or
    ///   miscounts (the serving layer returns a typed ERROR frame).
    pub fn new<E, P>(window: Duration, max_batch: usize, exec: E, on_panic: P) -> Batcher<Q, R>
    where
        E: Fn(Vec<Q>) -> Vec<R> + Send + 'static,
        P: Fn(&str) -> R + Send + 'static,
    {
        let max_batch = max_batch.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { jobs: VecDeque::new(), closed: false }),
            arrived: Condvar::new(),
        });
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                Self::dispatch_loop(&shared, window, max_batch, exec, on_panic)
            })
        };
        Batcher { shared, dispatcher: Some(dispatcher) }
    }

    fn dispatch_loop<E, P>(
        shared: &Shared<Q, R>,
        window: Duration,
        max_batch: usize,
        exec: E,
        on_panic: P,
    ) where
        E: Fn(Vec<Q>) -> Vec<R>,
        P: Fn(&str) -> R,
    {
        loop {
            let batch: Vec<Job<Q, R>> = {
                let mut state = shared.state.lock().unwrap();
                // sleep until the first query (or shutdown)
                while state.jobs.is_empty() && !state.closed {
                    state = shared.arrived.wait(state).unwrap();
                }
                if state.jobs.is_empty() && state.closed {
                    return;
                }
                // the window opens at the first queued query
                let deadline = Instant::now() + window;
                while state.jobs.len() < max_batch && !state.closed {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (next, timeout) =
                        shared.arrived.wait_timeout(state, deadline - now).unwrap();
                    state = next;
                    if timeout.timed_out() {
                        break;
                    }
                }
                let take = state.jobs.len().min(max_batch);
                state.jobs.drain(..take).collect()
            };
            let (queries, slots): (Vec<Q>, Vec<Arc<Slot<R>>>) =
                batch.into_iter().map(|j| (j.query, j.slot)).unzip();
            let n = queries.len();
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec(queries)));
            match outcome {
                Ok(results) if results.len() == n => {
                    for (slot, r) in slots.iter().zip(results) {
                        slot.fulfill(r);
                    }
                }
                Ok(results) => {
                    let msg = format!(
                        "batch executor returned {} results for {n} queries",
                        results.len()
                    );
                    for slot in &slots {
                        slot.fulfill(on_panic(&msg));
                    }
                }
                Err(payload) => {
                    let msg = crate::util::pool::panic_message(payload.as_ref());
                    for slot in &slots {
                        slot.fulfill(on_panic(&msg));
                    }
                }
            }
        }
    }

    /// Queue one query and block until its batch executes.  Called from
    /// connection workers; safe from any number of threads.
    pub fn submit(&self, query: Q) -> R {
        let slot = Slot::new();
        {
            let mut state = self.shared.state.lock().unwrap();
            state.jobs.push_back(Job { query, slot: Arc::clone(&slot) });
        }
        self.shared.arrived.notify_all();
        slot.wait()
    }

    /// Queries currently waiting for a batch (diagnostics).
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }
}

impl<Q: Send + 'static, R: Send + 'static> Drop for Batcher<Q, R> {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().closed = true;
        self.shared.arrived.notify_all();
        if let Some(d) = self.dispatcher.take() {
            // the dispatcher drains queued jobs before exiting, so no
            // submitter is left hanging
            d.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn single_query_roundtrips() {
        let b = Batcher::new(
            Duration::from_millis(1),
            8,
            |qs: Vec<u32>| qs.into_iter().map(|q| q * 2).collect(),
            |e| panic!("unexpected batch failure: {e}"),
        );
        assert_eq!(b.submit(21), 42);
        assert_eq!(b.submit(0), 0);
    }

    #[test]
    fn concurrent_submissions_coalesce_and_stay_ordered() {
        let batches = Arc::new(AtomicUsize::new(0));
        let bc = Arc::clone(&batches);
        // a wide window so concurrent submitters land in one batch
        let b = Arc::new(Batcher::new(
            Duration::from_millis(50),
            64,
            move |qs: Vec<u64>| {
                bc.fetch_add(1, Ordering::SeqCst);
                qs.into_iter().map(|q| q + 1000).collect()
            },
            |e: &str| panic!("unexpected: {e}"),
        ));
        let results: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16u64)
                .map(|i| {
                    let b = Arc::clone(&b);
                    s.spawn(move || b.submit(i))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // every submitter got *its own* answer, not a neighbor's
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, i as u64 + 1000);
        }
        let n = batches.load(Ordering::SeqCst);
        assert!(n < 16, "16 concurrent submissions ran as {n} batches — nothing coalesced");
    }

    #[test]
    fn max_batch_caps_execution_size() {
        let seen_max = Arc::new(AtomicUsize::new(0));
        let sm = Arc::clone(&seen_max);
        let b = Arc::new(Batcher::new(
            Duration::from_millis(30),
            3,
            move |qs: Vec<usize>| {
                sm.fetch_max(qs.len(), Ordering::SeqCst);
                qs
            },
            |e: &str| panic!("unexpected: {e}"),
        ));
        std::thread::scope(|s| {
            for i in 0..10 {
                let b = Arc::clone(&b);
                s.spawn(move || b.submit(i));
            }
        });
        let m = seen_max.load(Ordering::SeqCst);
        assert!(m <= 3, "batch of {m} exceeded max_batch=3");
    }

    #[test]
    fn panicking_executor_fails_the_batch_not_the_batcher() {
        let b = Batcher::new(
            Duration::from_millis(1),
            8,
            |qs: Vec<i32>| {
                if qs.contains(&-1) {
                    panic!("poison query");
                }
                qs.into_iter().map(Ok).collect()
            },
            |e: &str| Err(e.to_string()),
        );
        assert_eq!(b.submit(-1), Err("poison query".to_string()));
        // the dispatcher survived: the next clean batch still works
        assert_eq!(b.submit(7), Ok(7));
    }

    #[test]
    fn miscounting_executor_is_reported_not_deadlocked() {
        let b = Batcher::new(
            Duration::from_millis(1),
            8,
            |_qs: Vec<u8>| Vec::<Result<u8, String>>::new(),
            |e: &str| Err(e.to_string()),
        );
        let err = b.submit(1).unwrap_err();
        assert!(err.contains("0 results for 1"), "got {err}");
    }

    #[test]
    fn drop_drains_queued_jobs() {
        // submit from another thread, drop the batcher promptly: the
        // submitter must still get an answer, not hang forever
        let b = Arc::new(Batcher::new(
            Duration::from_millis(5),
            4,
            |qs: Vec<u32>| qs,
            |e: &str| panic!("unexpected: {e}"),
        ));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.submit(9));
        assert_eq!(h.join().unwrap(), 9);
        drop(b);
    }
}
