//! Sharded serving: one logical ANN index fanned across several
//! [`FittedModel`] artifacts, with scatter-gather top-k merging.
//!
//! ## Why shards
//!
//! A fit over a dataset that does not fit one machine's fit budget (or
//! whose artifact should stay under a size cap) is run as several
//! independent fits over contiguous row ranges; each produces its own
//! GKMODEL artifact with its own KNN graph over its own rows.  The
//! serve layer loads all of them and presents the union: a query fans
//! out to every shard with the *same* `topk`/`ef`, each shard answers
//! from its local graph, and the gather step merges the per-shard hits
//! into one global top-k.
//!
//! ## Id space and merge order
//!
//! Shard `s` holds rows `[base(s), base(s) + n_train(s))` of the union,
//! where `base` is the cumulative row count of the shards *in load
//! order* — so global ids depend only on the order models are given to
//! [`ShardedIndex::new`].  The merge sorts by `(d², global id)`
//! ascending — exactly the tie-break
//! [`TopK::into_sorted`](crate::core_ops::topk::TopK) uses — so a
//! sharded search over a split dataset returns *identically* what a
//! single-model search over the union returns whenever the per-shard
//! searches are exact (pinned by `tests/serve.rs`).

use crate::data::matrix::VecSet;
use crate::gkm::ann::SearchParams;
use crate::model::{ExtendReport, FittedModel};
use crate::runtime::{RtError, RtResult};

/// One logical index over one or more model shards.
#[derive(Debug)]
pub struct ShardedIndex {
    shards: Vec<FittedModel>,
    /// `bases[s]` = global id of shard `s`'s row 0 (cumulative rows).
    bases: Vec<u32>,
    total_rows: usize,
    dim: usize,
}

impl ShardedIndex {
    /// Assemble an index from shards in global-id order.  All shards
    /// must agree on dimensionality; every shard must be able to serve
    /// ANN queries (graph + retained vectors) for `search` to work —
    /// that precondition is checked lazily per call, like
    /// [`FittedModel::search`] does.
    pub fn new(shards: Vec<FittedModel>) -> RtResult<ShardedIndex> {
        if shards.is_empty() {
            return Err(RtError::msg("a sharded index needs at least one model"));
        }
        let dim = shards[0].dim;
        let mut bases = Vec::with_capacity(shards.len());
        let mut total: usize = 0;
        for (s, m) in shards.iter().enumerate() {
            if m.dim != dim {
                return Err(RtError::msg(format!(
                    "shard {s} has dim {} but shard 0 has dim {dim}",
                    m.dim
                )));
            }
            if total + m.n_train > u32::MAX as usize {
                return Err(RtError::msg("union exceeds the u32 id space"));
            }
            bases.push(total as u32);
            total += m.n_train;
        }
        Ok(ShardedIndex { shards, bases, total_rows: total, dim })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Rows in the union (sum of shard training sets).
    pub fn total_rows(&self) -> usize {
        self.total_rows
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Borrow the shard models (read-only; the serve loop uses this for
    /// cache-stats aggregation and config echo).
    pub fn shards(&self) -> &[FittedModel] {
        &self.shards
    }

    /// Mutably borrow the shard models (the server uses this to apply a
    /// `--threads` override before serving starts; geometry fields must
    /// not change — `bases`/`dim` are fixed at construction).
    pub fn shards_mut(&mut self) -> &mut [FittedModel] {
        &mut self.shards
    }

    /// Append `rows` to the union by extending the **last** shard in
    /// place ([`FittedModel::extend`]: assign, append, localized graph
    /// repair).  Global ids are cumulative over shards in load order, so
    /// growing the tail is the only append that leaves every existing
    /// global id stable — the new rows take the top of the id space.
    /// In-memory only: the shards' artifact files are not rewritten;
    /// persisting a grown index is [`FittedModel::save`] on the owning
    /// model.
    pub fn extend_rows(&mut self, rows: &VecSet) -> RtResult<ExtendReport> {
        if rows.dim() != self.dim {
            return Err(RtError::msg(format!(
                "extend rows have dim {} but the index has dim {}",
                rows.dim(),
                self.dim
            )));
        }
        if self.total_rows + rows.rows() > u32::MAX as usize {
            return Err(RtError::msg("extend would overflow the u32 global id space"));
        }
        let tail = self.shards.last_mut().expect("an index has at least one shard");
        let report = tail.extend(rows)?;
        self.total_rows += report.added;
        Ok(report)
    }

    /// Whether any shard pages its vectors from disk.
    pub fn any_disk_backed(&self) -> bool {
        self.shards.iter().any(|m| m.cache_stats().is_some())
    }

    /// Aggregate chunk-cache ledger `(hits, misses)` across disk-backed
    /// shards; `None` when everything is resident.
    pub fn cache_totals(&self) -> Option<(u64, u64)> {
        let mut any = false;
        let (mut h, mut m) = (0u64, 0u64);
        for shard in &self.shards {
            if let Some(cs) = shard.cache_stats() {
                any = true;
                h += cs.hits();
                m += cs.misses();
            }
        }
        any.then_some((h, m))
    }

    /// Batched nearest-centroid assignment.  Shards are independent
    /// *fits*, so their centroid sets differ; by convention the logical
    /// index answers `predict` from shard 0's centroids (the primary
    /// model — single-shard deployments get exactly
    /// [`FittedModel::try_predict_batch`]).
    pub fn predict_batch(&self, queries: &VecSet) -> RtResult<Vec<Result<u32, String>>> {
        self.shards[0].try_predict_batch(queries)
    }

    /// Scatter-gather batched ANN search: every shard runs the degraded
    /// batch kernel with the same `topk`/`params`, local hit ids are
    /// lifted to global ids, and each query's per-shard hit lists merge
    /// into one ascending `(d², global id)` top-k.
    ///
    /// A query that failed on *any* shard reports `Err` (its global
    /// top-k can no longer be guaranteed); other queries in the batch
    /// are unaffected.  The outer `Err` is a worker dying outside the
    /// per-query guards.
    pub fn search_batch(
        &self,
        queries: &VecSet,
        topk: usize,
        params: &SearchParams,
    ) -> RtResult<Vec<Result<Vec<(f32, u32)>, String>>> {
        let nq = queries.rows();
        if nq == 0 {
            return Ok(Vec::new());
        }
        // scatter: shards run sequentially here — each shard's batch
        // kernel already fans its queries across the worker pool, so
        // nesting another thread layer would only oversubscribe
        let mut per_shard = Vec::with_capacity(self.shards.len());
        for (s, shard) in self.shards.iter().enumerate() {
            let res = shard
                .try_search_batch(queries, topk, params)
                .map_err(|e| e.context(format!("shard {s}")))?;
            per_shard.push(res);
        }
        // gather: merge each query's shard hit lists
        let mut out = Vec::with_capacity(nq);
        for q in 0..nq {
            let mut merged: Vec<(f32, u32)> = Vec::with_capacity(topk * self.shards.len());
            let mut failure: Option<String> = None;
            for (s, res) in per_shard.iter().enumerate() {
                match &res[q] {
                    Ok(hits) => {
                        let base = self.bases[s];
                        merged.extend(hits.iter().map(|&(d, id)| (d, base + id)));
                    }
                    Err(e) => {
                        failure = Some(format!("shard {s}: {e}"));
                        break;
                    }
                }
            }
            out.push(match failure {
                Some(e) => Err(e),
                None => {
                    // the TopK tie-break: distance ascending, id ascending
                    merged.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
                    merged.truncate(topk);
                    Ok(merged)
                }
            });
        }
        Ok(out)
    }

    /// Single-query convenience over [`ShardedIndex::search_batch`].
    pub fn search(
        &self,
        query: &[f32],
        topk: usize,
        params: &SearchParams,
    ) -> Result<Vec<(f32, u32)>, String> {
        if query.len() != self.dim {
            return Err(format!("query dim {} != index dim {}", query.len(), self.dim));
        }
        let queries = VecSet::from_flat(self.dim, query.to_vec());
        let mut out = self
            .search_batch(&queries, topk, params)
            .map_err(|e| e.to_string())?;
        out.pop().expect("one query in, one result out")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::model::{Clusterer, GkMeans, RunContext};
    use crate::runtime::Backend;

    fn fit_shard(data: &VecSet, seed_k: usize) -> FittedModel {
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(2).keep_data(true);
        GkMeans::new(seed_k).kappa(6).tau(2).xi(25).fit(data, &ctx)
    }

    #[test]
    fn bases_cover_the_union_in_load_order() {
        let a = blobs(&BlobSpec::quick(120, 5, 3), 1);
        let c = blobs(&BlobSpec::quick(80, 5, 3), 2);
        let idx = ShardedIndex::new(vec![fit_shard(&a, 3), fit_shard(&c, 3)]).unwrap();
        assert_eq!(idx.num_shards(), 2);
        assert_eq!(idx.total_rows(), 200);
        assert_eq!(idx.bases, vec![0, 120]);
        assert_eq!(idx.dim(), 5);
        assert!(!idx.any_disk_backed());
        assert!(idx.cache_totals().is_none());
    }

    #[test]
    fn mismatched_dims_are_rejected() {
        let a = blobs(&BlobSpec::quick(60, 4, 2), 3);
        let c = blobs(&BlobSpec::quick(60, 6, 2), 4);
        let err = ShardedIndex::new(vec![fit_shard(&a, 2), fit_shard(&c, 2)]).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        assert!(ShardedIndex::new(Vec::new()).is_err());
    }

    #[test]
    fn single_shard_search_matches_the_model() {
        let data = blobs(&BlobSpec::quick(150, 6, 4), 5);
        let model = fit_shard(&data, 4);
        let params = SearchParams::default();
        let want = model.search(data.row(3), 5, &params).unwrap();
        let idx = ShardedIndex::new(vec![model]).unwrap();
        let got = idx.search(data.row(3), 5, &params).unwrap();
        assert_eq!(got, want, "one shard must behave exactly like the bare model");
    }

    #[test]
    fn extend_grows_the_tail_shard_and_keeps_bases_stable() {
        let a = blobs(&BlobSpec::quick(120, 5, 3), 7);
        let c = blobs(&BlobSpec::quick(90, 5, 3), 8);
        let extra = blobs(&BlobSpec::quick(30, 5, 3), 9);
        let mut idx = ShardedIndex::new(vec![fit_shard(&a, 3), fit_shard(&c, 3)]).unwrap();
        let report = idx.extend_rows(&extra).unwrap();
        assert_eq!(report.added, 30);
        assert_eq!(idx.total_rows(), 240);
        assert_eq!(idx.bases, vec![0, 120], "existing global ids must not move");
        assert_eq!(idx.shards()[1].n_train, 120, "the tail shard absorbs the rows");
        // the appended rows are reachable through a union search: each
        // extra row's global id lives in the tail shard's id range
        let hits = idx.search(extra.row(0), 3, &SearchParams::default()).unwrap();
        assert!(!hits.is_empty());
        assert!(hits[0].1 >= 120, "nearest hit should be an appended (tail-shard) row");
        // dim mismatch is a typed error
        let wrong = blobs(&BlobSpec::quick(10, 4, 2), 10);
        assert!(idx.extend_rows(&wrong).is_err());
    }

    #[test]
    fn predict_routes_to_the_primary_shard() {
        let data = blobs(&BlobSpec::quick(100, 4, 3), 6);
        let model = fit_shard(&data, 3);
        let want = model.predict_batch(&data);
        let idx = ShardedIndex::new(vec![model]).unwrap();
        let got = idx.predict_batch(&data).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(*g.as_ref().unwrap(), *w);
        }
    }
}
