//! `gkmeans` — the launcher.
//!
//! ```text
//! gkmeans cluster   --data sift:100000 --k 1000 --method gkmeans [--kappa 50 --tau 10 --xi 50]
//!                   [--save model.gkm --keep-data]
//! gkmeans predict   --model model.gkm --data sift:10000 [--out labels.ivecs]
//! gkmeans graph     --data sift:100000 --kappa 50 --tau 10 [--out graph.ivecs] [--recall]
//! gkmeans search    --data sift:100000 --queries 100 --topk 10 [--ef 64]
//! gkmeans search    --model model.gkm --queries 100 --topk 10   # serve a saved artifact
//! gkmeans compare   --data sift:20000 --k 200        # all methods, Tab.2-style table
//! gkmeans info                                        # backend + artifact status
//! ```
//!
//! Every subcommand accepts `--backend native|pjrt|auto` (default auto),
//! `--seed N`, `--iters N`, `--config file.conf` (CLI overrides config).
//! All clustering routes through the `model::Clusterer` fit → model API;
//! `cluster --save` persists the `FittedModel`, `predict`/`search --model`
//! serve it back.

use std::path::Path;

use gkmeans::coordinator::job::{ClusterJob, JobResult, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::eval::report::Table;
use gkmeans::gkm::{ann, construct};
use gkmeans::model::{ExtendParams, FittedModel};
use gkmeans::runtime::Backend;
use gkmeans::util::cli::{parse_env, Args};
use gkmeans::util::configfile::Config;
use gkmeans::util::rng::Rng;
use gkmeans::util::timer::{fmt_secs, Timer};

const VALUED: &[&str] = &[
    "data", "k", "kappa", "tau", "xi", "method", "backend", "seed", "iters", "out", "queries",
    "topk", "ef", "config", "recall-samples", "threads", "save", "model", "scan-order",
    "checkpoint", "checkpoint-every", "quantize", "route", "route-beam", "route-branch",
    "refine-drift",
];

fn main() {
    let args = parse_env(VALUED);
    let code = match args.subcommand.as_deref() {
        Some("cluster") => cmd_cluster(&args),
        Some("predict") => cmd_predict(&args),
        Some("extend") => cmd_extend(&args),
        Some("graph") => cmd_graph(&args),
        Some("search") => cmd_search(&args),
        Some("compare") => cmd_compare(&args),
        Some("info") => cmd_info(),
        _ => {
            eprint!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "\
gkmeans — fast k-means driven by a KNN graph (Deng & Zhao 2017)

USAGE:
  gkmeans cluster --data <spec> --k <k> [--method gkmeans] [--save FILE [--keep-data]]
                  [--quantize sq8] [--stream]
                  [--checkpoint DIR [--checkpoint-every N] [--resume]] [options]
  gkmeans predict --model FILE --data <spec> [--out labels.ivecs]
  gkmeans extend  --model FILE --data <spec> [--refine-drift T]
  gkmeans graph   --data <spec> [--kappa 50 --tau 10 --xi 50] [--recall]
  gkmeans search  --data <spec> | --model FILE  [--queries 100 --topk 10 --ef 64]
  gkmeans compare --data <spec> --k <k> [--iters 30]
  gkmeans info

DATASET SPECS:
  sift:N | vlad:N | glove:N | gist:N | blobs:N [:seed=S]   synthetic
  path/to/file.fvecs | .bvecs                              on-disk

COMMON OPTIONS:
  --backend native|pjrt|auto   compute backend (default auto)
  --seed N                     RNG seed (default 20170707)
  --iters N                    max epochs (default 30)
  --threads N                  worker threads (default 1 = serial,
                               0 = auto-detect; parallelizes GK-means
                               epochs, NN-Descent, graph builds, 2M-tree,
                               and model predict)
  --save FILE                  persist the fitted model artifact (GKMODEL v2:
                               section-offset layout; `search`/`predict`
                               --model page the vectors from disk)
  --keep-data                  carry the training vectors in the artifact
                               (required for `search --model`)
  --quantize sq8               attach an SQ8 code store to the model
                               (needs --keep-data): searches traverse
                               RAM-resident u8 codes (~4× smaller than
                               f32) and re-rank candidates exactly;
                               persisted in the artifact (QVECTORS)
  --stream                     cluster file-backed datasets out-of-core
                               (fixed-size row blocks + resident cache
                               instead of one in-RAM buffer)
  --scan-order MODE            epoch visit order: auto (default; chunk-
                               aligned super-block shuffles on streamed
                               stores, global on resident data), global
                               (historical full shuffle everywhere), or
                               superblock (request locality planning)
  --route tree                 (cluster) build a hierarchical routing tree
                               over the centroids and persist it in the
                               artifact (RTREE): predict/search descend
                               O(depth·branch) instead of scanning all k —
                               the large-k fast path (engages at k ≥ 1024)
  --route tree|off             (predict/search) force routing on for any k,
                               or disable a persisted tree for this run
  --route-branch N             (cluster) tree fan-out per node (default 32)
  --route-beam B               beam width: nodes kept per level (default 8;
                               larger = closer to the exact flat scan,
                               B ≥ k is bit-identical to it)
  --checkpoint DIR             write a fit.gkckpt checkpoint into DIR
                               periodically during the fit (crash-safe:
                               temp file + fsync + rename)
  --checkpoint-every N         epochs between checkpoints (default 1)
  --resume                     continue from DIR's checkpoint if present
                               (bit-identical to the uninterrupted fit
                               at --threads 1); starts fresh otherwise
  --refine-drift T             (extend) re-run bounded Δℐ refinement over
                               cells whose mean distortion drifted past
                               baseline·(1+T) after the append; oversized
                               dirty cells split (new centroids join the
                               routing tree in place).  Off by default —
                               the default extend is pinned deterministic
  --config FILE                key=value config file (CLI overrides)
  --verbose / --quiet          log level
";

/// Merge config-file values (if `--config`) under CLI options.
fn effective(args: &Args) -> Args {
    let mut merged = args.clone();
    if let Some(path) = args.get("config") {
        match Config::load(std::path::Path::new(path)) {
            Ok(cfg) => {
                for key in cfg.keys().map(|s| s.to_string()).collect::<Vec<_>>() {
                    let short = key.rsplit('.').next().unwrap_or(&key).to_string();
                    if !merged.options.contains_key(&short) {
                        if let Some(v) = cfg.get(&key) {
                            merged.options.insert(short, v.to_string());
                        }
                    }
                }
            }
            Err(e) => eprintln!("warning: {e}"),
        }
    }
    if merged.flag("verbose") {
        gkmeans::util::logging::set_level(gkmeans::util::logging::Level::Debug);
    } else if merged.flag("quiet") {
        gkmeans::util::logging::set_level(gkmeans::util::logging::Level::Warn);
    }
    merged
}

fn backend_of(args: &Args) -> Backend {
    match args.get_or("backend", "auto") {
        "native" => Backend::native(),
        "pjrt" => match Backend::pjrt(&gkmeans::runtime::artifact::default_dir()) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("error: PJRT backend unavailable: {e:#}");
                std::process::exit(1);
            }
        },
        _ => Backend::auto(),
    }
}

fn dataset_of(args: &Args) -> DatasetSpec {
    let spec = args.get("data").unwrap_or("blobs:10000");
    match DatasetSpec::parse(spec) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

fn scan_order_of(args: &Args) -> gkmeans::data::plan::ScanOrder {
    match gkmeans::data::plan::ScanOrder::parse(args.get_or("scan-order", "auto")) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

fn job_of(args: &Args) -> ClusterJob {
    let method = match Method::parse(args.get_or("method", "gkmeans")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let mut job = ClusterJob::new(dataset_of(args), method, args.usize_or("k", 100));
    job.kappa = args.usize_or("kappa", 50);
    job.tau = args.usize_or("tau", 10);
    job.xi = args.usize_or("xi", 50);
    job.base.max_iters = args.usize_or("iters", 30);
    job.base.seed = args.u64_or("seed", 20170707);
    job.base.threads = args.usize_or("threads", 1);
    job.base.scan_order = scan_order_of(args);
    job.measure_recall = args.flag("recall");
    job.keep_data = args.flag("keep-data");
    job.checkpoint = args
        .get("checkpoint")
        .map(|d| (std::path::PathBuf::from(d), args.usize_or("checkpoint-every", 1)));
    job.resume = args.flag("resume");
    if job.resume && job.checkpoint.is_none() {
        eprintln!("error: --resume needs --checkpoint DIR to name the checkpoint directory");
        std::process::exit(2);
    }
    job
}

fn print_result(r: &JobResult) {
    println!(
        "method={} n={} d={} k={}",
        r.method.name(),
        r.n,
        r.dim,
        r.k
    );
    println!(
        "init={} iter={} total={}",
        fmt_secs(r.init_seconds),
        fmt_secs(r.iter_seconds),
        fmt_secs(r.total_seconds)
    );
    println!("distortion={:.6}", r.distortion);
    if let Some(rec) = r.recall {
        println!("graph_recall@1={rec:.3}");
    }
}

fn cmd_cluster(args: &Args) -> i32 {
    let args = effective(args);
    let job = job_of(&args);
    let backend = backend_of(&args);
    // --stream: file-backed datasets cluster out-of-core through the
    // chunked storage layer instead of materializing in RAM
    let data: Box<dyn gkmeans::data::store::VecStore> = if args.flag("stream") {
        match job.dataset.open_store() {
            Ok(d) => d,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    } else {
        match job.dataset.load() {
            Ok(d) => Box::new(d),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    };
    let (mut model, rec) = pipeline::fit_job(&job, data.as_ref(), &backend);
    print_result(&pipeline::result_from_model(&model, rec));
    if let Some(mode) = args.get("quantize") {
        if mode != "sq8" {
            eprintln!("error: unknown --quantize mode {mode:?} (supported: sq8)");
            return 2;
        }
        if let Err(e) = model.quantize_sq8(0) {
            eprintln!("error: {e}");
            return 1;
        }
        let q = model.quantized.as_ref().expect("quantize_sq8 just succeeded");
        println!(
            "quantized: sq8 codes resident ({} bytes{})",
            q.resident_bytes(),
            if q.quantizer().is_identity() { ", lossless u8 passthrough" } else { "" }
        );
    }
    match args.get("route") {
        Some("tree") => {
            let branch = args.usize_or("route-branch", gkmeans::gkm::tree::DEFAULT_BRANCH);
            if branch < 2 {
                eprintln!("error: --route-branch must be ≥ 2 (got {branch})");
                return 2;
            }
            let params = gkmeans::gkm::tree::RouteTreeParams {
                branch,
                beam: args.usize_or("route-beam", gkmeans::gkm::tree::DEFAULT_BEAM).max(1),
                seed: args.u64_or("seed", 20170707),
                threads: args.usize_or("threads", 1),
            };
            model.build_route(&params);
            let t = model.route.as_ref().expect("build_route just ran");
            println!(
                "route: tree built (branch={}, beam={}, nodes={}, depth={}{})",
                t.branch,
                t.default_beam,
                t.nodes(),
                t.depth(),
                if t.has_reps() { ", seeded" } else { "" }
            );
        }
        Some("off") | None => {}
        Some(other) => {
            eprintln!("error: unknown --route mode {other:?} (supported: tree, off)");
            return 2;
        }
    }
    if let Some(path) = args.get("save") {
        if let Err(e) = model.save(Path::new(path)) {
            eprintln!("error: {e}");
            return 1;
        }
        let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        println!("saved model to {path} ({bytes} bytes, GKMODEL v2)");
        if model.graph.is_some() && model.data.is_none() {
            println!(
                "note: vectors not embedded (pass --keep-data to serve `search --model`)"
            );
        }
    }
    0
}

/// Apply `--route` / `--route-beam` serving overrides to a loaded model:
/// `off` drops a persisted tree for this run, `tree` forces routing on
/// regardless of k, and `--route-beam` retunes the persisted beam width.
fn apply_route_flags(model: &mut FittedModel, args: &Args) -> Result<(), String> {
    match args.get("route") {
        Some("off") => model.route = None,
        Some("tree") => {
            if model.route.is_none() {
                return Err(
                    "model carries no routing tree (refit with `cluster --route tree`)".into(),
                );
            }
            model.route_min_k = 0;
        }
        None => {}
        Some(other) => {
            return Err(format!("unknown --route mode {other:?} (supported: tree, off)"))
        }
    }
    if let Some(raw) = args.get("route-beam") {
        let beam: u32 = raw
            .parse()
            .ok()
            .filter(|&b| b >= 1)
            .ok_or_else(|| format!("--route-beam must be a positive integer (got {raw:?})"))?;
        match model.route.as_mut() {
            Some(t) => t.default_beam = beam,
            None => return Err("--route-beam needs a model with a routing tree".into()),
        }
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> i32 {
    let args = effective(args);
    let model_path = match args.get("model") {
        Some(p) => p,
        None => {
            eprintln!("error: predict needs --model FILE (from `cluster --save`)");
            return 2;
        }
    };
    let mut model = match FittedModel::load(Path::new(model_path)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    model.threads = args.usize_or("threads", model.threads);
    if let Err(e) = apply_route_flags(&mut model, &args) {
        eprintln!("error: {e}");
        return 2;
    }
    if model.routing_active() {
        let t = model.route.as_ref().expect("routing_active implies a tree");
        println!(
            "routing: tree (branch={}, beam={}, depth={})",
            t.branch,
            t.default_beam,
            t.depth()
        );
    }
    let data = match dataset_of(&args).load() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    if data.dim() != model.dim {
        eprintln!(
            "error: dataset dim {} != model dim {} (model was fitted on {}, n={})",
            data.dim(),
            model.dim,
            model.method.name(),
            model.n_train
        );
        return 1;
    }
    let timer = Timer::start();
    let labels = model.predict(&data);
    let secs = timer.elapsed_s();
    let mut counts = vec![0u64; model.k];
    for &l in &labels {
        counts[l as usize] += 1;
    }
    let nonempty = counts.iter().filter(|&&c| c > 0).count();
    println!(
        "predicted {} samples into {} of k={} clusters in {} ({:.0} samples/s)",
        labels.len(),
        nonempty,
        model.k,
        fmt_secs(secs),
        labels.len() as f64 / secs.max(1e-12)
    );
    if let Some(path) = args.get("out") {
        let rows: Vec<Vec<i32>> = labels.iter().map(|&l| vec![l as i32]).collect();
        if let Err(e) = gkmeans::data::io::write_ivecs(std::path::Path::new(path), &rows) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// Grow a saved artifact in place: load, assign + append the new rows,
/// repair the graph with localized joins, optionally drift-refine, and
/// atomically resave to the same path.
fn cmd_extend(args: &Args) -> i32 {
    let args = effective(args);
    let model_path = match args.get("model") {
        Some(p) => p,
        None => {
            eprintln!("error: extend needs --model FILE (from `cluster --save --keep-data`)");
            return 2;
        }
    };
    let mut model = match FittedModel::load(Path::new(model_path)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    model.threads = args.usize_or("threads", model.threads);
    let data = match dataset_of(&args).load() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let mut params = ExtendParams { seed: args.u64_or("seed", 20170707), ..Default::default() };
    if let Some(raw) = args.get("refine-drift") {
        match raw.parse::<f64>() {
            Ok(t) if t >= 0.0 => params.refine_drift = Some(t),
            _ => {
                eprintln!("error: --refine-drift must be a non-negative number (got {raw:?})");
                return 2;
            }
        }
    }
    let timer = Timer::start();
    let report = match model.extend_with(&data, &params) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let secs = timer.elapsed_s();
    println!(
        "extended {model_path}: {} -> {} rows (+{}) in {} ({} cells touched, {} graph updates)",
        report.n_before,
        report.n_after,
        report.added,
        fmt_secs(secs),
        report.cells_touched,
        report.graph_updates
    );
    if params.refine_drift.is_some() {
        println!(
            "drift: {} dirty cells, {} refinement moves, {} new centroids (k={})",
            report.dirty_cells,
            report.refine_moves,
            report.new_centroids,
            model.k
        );
    }
    if let Err(e) = model.save(Path::new(model_path)) {
        eprintln!("error: {e}");
        return 1;
    }
    let bytes = std::fs::metadata(model_path).map(|m| m.len()).unwrap_or(0);
    println!("saved model to {model_path} ({bytes} bytes, GKMODEL v2)");
    0
}

fn cmd_graph(args: &Args) -> i32 {
    let args = effective(args);
    let backend = backend_of(&args);
    let data = match dataset_of(&args).load() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let params = construct::ConstructParams {
        kappa: args.usize_or("kappa", 50),
        tau: args.usize_or("tau", 10),
        xi: args.usize_or("xi", 50),
        seed: args.u64_or("seed", 20170707),
        threads: args.usize_or("threads", 1),
        scan_order: scan_order_of(&args),
    };
    let out = construct::build(&data, &params, &backend);
    println!(
        "graph built: n={} kappa={} tau={} in {}",
        out.graph.n(),
        out.graph.kappa(),
        params.tau,
        fmt_secs(out.total_seconds)
    );
    for h in &out.history {
        println!(
            "  round {:>2}: t={:>8} cell-distortion={:.5} updates={}",
            h.round,
            fmt_secs(h.seconds),
            h.distortion,
            h.updates
        );
    }
    if args.flag("recall") {
        let rec = if data.rows() <= 20_000 {
            let exact = gkmeans::graph::brute::build_threaded(
                &data,
                1,
                &Backend::native(),
                params.threads,
            );
            gkmeans::graph::recall::recall_at_1(&out.graph, &exact)
        } else {
            gkmeans::graph::recall::sampled_recall_at_1(
                &data,
                &out.graph,
                args.usize_or("recall-samples", 100),
                params.seed,
            )
        };
        println!("recall@1={rec:.3}");
    }
    if let Some(path) = args.get("out") {
        let rows: Vec<Vec<i32>> = (0..out.graph.n())
            .map(|i| out.graph.neighbors(i).iter().map(|&j| j as i32).collect())
            .collect();
        if let Err(e) = gkmeans::data::io::write_ivecs(std::path::Path::new(path), &rows) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// Serve ANN queries from a saved model artifact (`--model`) through the
/// batched, multi-threaded query path.
fn search_model(args: &Args) -> i32 {
    let model_path = match args.get("model") {
        Some(p) => p,
        None => {
            eprintln!("error: search --model needs a model file (from `cluster --save`)");
            return 2;
        }
    };
    let mut model = match FittedModel::load(Path::new(model_path)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    model.threads = args.usize_or("threads", model.threads);
    if let Err(e) = apply_route_flags(&mut model, args) {
        eprintln!("error: {e}");
        return 2;
    }
    if model.routing_active() {
        let t = model.route.as_ref().expect("routing_active implies a tree");
        println!(
            "routing: tree seeding (branch={}, beam={}{})",
            t.branch,
            t.default_beam,
            if t.has_reps() { "" } else { ", no reps — falling back to random entries" }
        );
    }
    let vecs = match model.data.as_ref() {
        Some(v) => v,
        None => {
            eprintln!(
                "error: {model_path} has no embedded vectors; refit with \
                 `cluster --save {model_path} --keep-data`"
            );
            return 1;
        }
    };
    println!(
        "serving {} ({} vectors, d={}, {}, graph {})",
        model_path,
        vecs.rows(),
        model.dim,
        if vecs.is_resident() { "resident" } else { "paged from disk" },
        model
            .graph
            .as_ref()
            .map(|g| format!("kappa={}", g.kappa()))
            .unwrap_or_else(|| "absent".into())
    );
    let nq = args.usize_or("queries", 100);
    let topk = args.usize_or("topk", 10);
    let sp = ann::SearchParams {
        ef: args.usize_or("ef", 64),
        seed: args.u64_or("seed", 20170707),
        ..Default::default()
    };
    // sample perturbed indexed vectors as the query batch (one cursor:
    // a paged store reuses its file handle + block cache across draws)
    use gkmeans::data::store::VecStore as _;
    let mut cur = vecs.open();
    let mut rng = Rng::new(sp.seed ^ 0x5EA5C);
    let mut qflat: Vec<f32> = Vec::with_capacity(nq * model.dim);
    for _ in 0..nq {
        let qi = rng.below(vecs.rows());
        qflat.extend(cur.row(qi).iter().map(|v| v + 0.001));
    }
    drop(cur);
    let queries = gkmeans::data::matrix::VecSet::from_flat(model.dim, qflat);
    let timer = Timer::start();
    let results = match model.search_batch(&queries, topk, &sp) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let total = timer.elapsed_s();
    let hits: usize = results.iter().filter(|r| !r.is_empty()).count();
    println!(
        "{nq} queries (threads={}): {} non-empty, avg latency={}, {:.0} queries/s",
        model.threads,
        hits,
        fmt_secs(total / nq.max(1) as f64),
        nq as f64 / total.max(1e-12)
    );
    0
}

fn cmd_search(args: &Args) -> i32 {
    let args = effective(args);
    if args.get("model").is_some() {
        return search_model(&args);
    }
    let backend = backend_of(&args);
    let data = match dataset_of(&args).load() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let seed = args.u64_or("seed", 20170707);
    let params = construct::ConstructParams {
        kappa: args.usize_or("kappa", 20),
        tau: args.usize_or("tau", 10),
        xi: args.usize_or("xi", 50),
        seed,
        threads: args.usize_or("threads", 1),
        scan_order: scan_order_of(&args),
    };
    let build = construct::build(&data, &params, &backend);
    println!("graph: {}", fmt_secs(build.total_seconds));
    let nq = args.usize_or("queries", 100);
    let topk = args.usize_or("topk", 10);
    let sp = ann::SearchParams { ef: args.usize_or("ef", 64), ..Default::default() };
    let mut rng = Rng::new(seed ^ 0x5EA5C);
    let timer = Timer::start();
    let mut evals = 0usize;
    for _ in 0..nq {
        let qi = rng.below(data.rows());
        let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.001).collect();
        let (_, stats) = ann::search(&data, &build.graph, &q, topk, &sp, &mut rng);
        evals += stats.dist_evals;
    }
    let total = timer.elapsed_s();
    println!(
        "{nq} queries: avg latency={} avg dist-evals={}",
        fmt_secs(total / nq as f64),
        evals / nq.max(1)
    );
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let args = effective(args);
    let backend = backend_of(&args);
    let data = match dataset_of(&args).load() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let mut table = Table::new(&["method", "init_s", "iter_s", "total_s", "distortion"]);
    for &m in Method::all() {
        let mut job = job_of(&args);
        job.method = m;
        let r = pipeline::run_job_on(&job, &data, &backend);
        table.row(&[
            m.name().into(),
            format!("{:.2}", r.init_seconds),
            format!("{:.2}", r.iter_seconds),
            format!("{:.2}", r.total_seconds),
            format!("{:.5}", r.distortion),
        ]);
    }
    println!("{}", table.render());
    0
}

fn cmd_info() -> i32 {
    println!("gkmeans {}", env!("CARGO_PKG_VERSION"));
    let dir = gkmeans::runtime::artifact::default_dir();
    match gkmeans::runtime::artifact::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} entries)", dir.display(), m.by_key.len());
            for ((entry, dim), a) in {
                let mut v: Vec<_> = m.by_key.iter().collect();
                v.sort_by_key(|(k, _)| (k.0.clone(), k.1));
                v
            } {
                println!("  {entry}_d{dim}: bm={} bn={} outputs={}", a.bm, a.bn, a.outputs);
            }
            match Backend::pjrt(&dir) {
                Ok(_) => println!("pjrt: OK"),
                Err(e) => println!("pjrt: FAILED ({e:#})"),
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — native backend only"),
    }
    0
}
