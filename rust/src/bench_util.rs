//! Shared plumbing for the bench harnesses (`rust/benches/*.rs`).
//!
//! Every harness regenerates one of the paper's tables/figures at a
//! machine-appropriate default scale; `GKMEANS_BENCH_SCALE` multiplies the
//! dataset sizes (e.g. `GKMEANS_BENCH_SCALE=10 cargo bench --bench
//! fig6_scalability` for a long run), and `GKMEANS_BENCH_FAST=1` shrinks
//! everything for smoke tests.

/// User-controlled scale multiplier.
pub fn scale() -> f64 {
    if std::env::var("GKMEANS_BENCH_FAST").is_ok() {
        return 0.2;
    }
    std::env::var("GKMEANS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Apply the scale to a default size (min 100).
pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(100)
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("================================================================");
    println!("{id}: {what}");
    println!("scale={} backend={}", scale(), backend().name());
    println!("================================================================");
}

/// The backend benches use (auto: PJRT when artifacts exist).
pub fn backend() -> crate::runtime::Backend {
    crate::runtime::Backend::auto()
}

#[cfg(test)]
mod tests {
    #[test]
    fn scaled_has_floor() {
        assert!(super::scaled(10) >= 100 || super::scale() >= 1.0);
        assert_eq!(super::scaled(1000).max(100), super::scaled(1000));
    }
}
