//! Shared plumbing for the bench harnesses (`rust/benches/*.rs`).
//!
//! Every harness regenerates one of the paper's tables/figures at a
//! machine-appropriate default scale; `GKMEANS_BENCH_SCALE` multiplies the
//! dataset sizes (e.g. `GKMEANS_BENCH_SCALE=10 cargo bench --bench
//! fig6_scalability` for a long run), and `GKMEANS_BENCH_FAST=1` shrinks
//! everything for smoke tests.
//!
//! [`GkBenchRecord`]/[`write_gk_bench_json`] give the perf-tracking
//! harnesses a machine-readable trajectory file (`BENCH_gkm.json`) so
//! future PRs can compare epoch throughput against this one.

/// User-controlled scale multiplier.
pub fn scale() -> f64 {
    if std::env::var("GKMEANS_BENCH_FAST").is_ok() {
        return 0.2;
    }
    std::env::var("GKMEANS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Apply the scale to a default size (min 100).
pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(100)
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("================================================================");
    println!("{id}: {what}");
    println!("scale={} backend={}", scale(), backend().name());
    println!("================================================================");
}

/// The backend benches use (auto: PJRT when artifacts exist).
pub fn backend() -> crate::runtime::Backend {
    crate::runtime::Backend::auto()
}

/// One epoch-throughput measurement destined for `BENCH_gkm.json`.
#[derive(Debug, Clone)]
pub struct GkBenchRecord {
    /// Measurement name (e.g. `gk_epoch`).
    pub name: String,
    /// Dataset rows.
    pub n: usize,
    /// Dimensionality.
    pub d: usize,
    /// Cluster count.
    pub k: usize,
    /// Graph neighbors consulted (κ).
    pub kappa: usize,
    /// Worker threads the measurement ran with.
    pub threads: usize,
    /// Epochs executed inside the timing window.
    pub epochs: usize,
    /// Throughput: samples scanned per second of epoch time.
    pub samples_per_s: f64,
}

impl GkBenchRecord {
    /// Hand-rolled JSON object (no serde in the offline build).  All
    /// fields are numeric except `name`, which the harnesses keep to
    /// `[a-z0-9_]`, so no escaping is required.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"n\":{},\"d\":{},\"k\":{},\"kappa\":{},\"threads\":{},\"epochs\":{},\"samples_per_s\":{:.1}}}",
            self.name, self.n, self.d, self.k, self.kappa, self.threads, self.epochs, self.samples_per_s
        )
    }
}

/// Write the perf-trajectory records as a JSON array.  Destination:
/// `$GKMEANS_BENCH_JSON` if set, else `BENCH_gkm.json` in the working
/// directory.  Returns the path written.
pub fn write_gk_bench_json(records: &[GkBenchRecord]) -> std::io::Result<std::path::PathBuf> {
    let path = std::env::var("GKMEANS_BENCH_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_gkm.json"));
    write_gk_bench_json_to(&path, records)?;
    Ok(path)
}

/// [`write_gk_bench_json`] with an explicit destination (also what tests
/// use — mutating the process environment from a multithreaded test
/// harness is a getenv/setenv race).
pub fn write_gk_bench_json_to(
    path: &std::path::Path,
    records: &[GkBenchRecord],
) -> std::io::Result<()> {
    let lines: Vec<String> = records.iter().map(|r| r.to_json()).collect();
    write_json_array(path, &lines)
}

/// Write pre-serialized JSON objects as one indented JSON array — the
/// shared framing for every bench trajectory file (`BENCH_gkm.json`,
/// `BENCH_oocore.json`).
pub fn write_json_array(path: &std::path::Path, lines: &[String]) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, l) in lines.iter().enumerate() {
        s.push_str("  ");
        s.push_str(l);
        if i + 1 < lines.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n");
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    #[test]
    fn scaled_has_floor() {
        assert!(super::scaled(10) >= 100 || super::scale() >= 1.0);
        assert_eq!(super::scaled(1000).max(100), super::scaled(1000));
    }

    #[test]
    fn bench_record_json_shape() {
        let r = super::GkBenchRecord {
            name: "gk_epoch".into(),
            n: 5000,
            d: 128,
            k: 100,
            kappa: 20,
            threads: 4,
            epochs: 7,
            samples_per_s: 123456.78,
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        for key in ["\"name\":\"gk_epoch\"", "\"threads\":4", "\"samples_per_s\":123456.8"] {
            assert!(j.contains(key), "{j} missing {key}");
        }
    }

    #[test]
    fn bench_json_roundtrips_to_disk() {
        let dir = std::env::temp_dir().join(format!("gkm_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_gkm.json");
        let recs = vec![super::GkBenchRecord {
            name: "x".into(),
            n: 1,
            d: 2,
            k: 3,
            kappa: 4,
            threads: 1,
            epochs: 1,
            samples_per_s: 10.0,
        }];
        super::write_gk_bench_json_to(&path, &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('['));
        assert!(text.contains("\"name\":\"x\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
