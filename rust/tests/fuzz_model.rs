//! Fuzz-style robustness tests for the GKMODEL artifact loader: a
//! seeded deterministic generator permutes, truncates, and bit-flips a
//! maximal v2 artifact (every section kind: META, LABELS, CENTROIDS,
//! GRAPH, VECTORS, CRC, QVECTORS, RTREE, DRIFT) and asserts the loader
//! either succeeds bit-exact or fails with a typed error — never
//! panics, never over-allocates on hostile length fields.

use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::gkm::tree::RouteTreeParams;
use gkmeans::model::{serde, Clusterer, DriftState, FittedModel, GkMeans, RunContext};
use gkmeans::runtime::Backend;
use gkmeans::testing::fault::splitmix64;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gkm_fuzz_{}_{name}", std::process::id()))
}

/// A model carrying every persistable section: graph, resident vectors,
/// SQ8 codes, routing tree, and drift baselines (one NaN = "unset").
fn maximal_model() -> FittedModel {
    let data = blobs(&BlobSpec::quick(220, 5, 4), 17);
    let b = Backend::native();
    let ctx = RunContext::new(&b).threads(1).max_iters(3).keep_data(true);
    let mut m = GkMeans::new(4).kappa(5).tau(2).xi(25).fit(&data, &ctx);
    m.quantize_sq8(0).unwrap();
    m.build_route(&RouteTreeParams::default());
    let mut drift = DriftState::unset(m.k);
    drift.baseline[0] = 0.25;
    drift.baseline[1] = 1.5;
    m.drift = Some(drift);
    m
}

/// Parse the v2 section table of `bytes`: `(kind, offset, len)` per
/// entry, in table order.  Test-side mirror of the on-disk layout
/// (`magic 8, version u32, count u32, count × { kind u32, reserved u32,
/// offset u64, len u64 }`).
fn table_of(bytes: &[u8]) -> Vec<(u32, usize, usize)> {
    let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    (0..count)
        .map(|i| {
            let at = 16 + 24 * i;
            let kind = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
            let off = u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap()) as usize;
            let len = u64::from_le_bytes(bytes[at + 16..at + 24].try_into().unwrap()) as usize;
            (kind, off, len)
        })
        .collect()
}

/// One deterministic mutation of `base`, derived only from `seed`.
fn mutate(base: &[u8], seed: u64) -> Vec<u8> {
    let mut bytes = base.to_vec();
    let h1 = splitmix64(seed);
    let h2 = splitmix64(h1 ^ 0xD1B5_4A32_D192_ED03);
    let h3 = splitmix64(h2 ^ 0x9E37_79B9_7F4A_7C15);
    match seed % 4 {
        0 => {
            // single bit flip anywhere (header, table, payload, padding)
            let pos = (h1 as usize) % bytes.len();
            bytes[pos] ^= 1 << (h2 % 8);
        }
        1 => {
            // truncation to any prefix, including mid-header
            bytes.truncate((h1 as usize) % bytes.len());
        }
        2 => {
            // 4-byte overwrite: clobbers kinds, counts, lengths, floats
            let pos = (h1 as usize) % (bytes.len() - 4);
            bytes[pos..pos + 4].copy_from_slice(&(h2 as u32).to_le_bytes());
        }
        _ => {
            // section-table attack: swap two whole entries, then
            // scribble one field (kind / offset / len) of a third
            let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
            let entry = |i: usize| 16 + 24 * i;
            let (a, b) = ((h1 as usize) % count, (h2 as usize) % count);
            if a != b {
                let (lo, hi) = (a.min(b), a.max(b));
                let (head, tail) = bytes.split_at_mut(entry(hi));
                head[entry(lo)..entry(lo) + 24].swap_with_slice(&mut tail[..24]);
            }
            let c = entry((h3 as usize) % count);
            match (h3 >> 8) % 3 {
                0 => bytes[c..c + 4].copy_from_slice(&(h3 as u32).to_le_bytes()),
                1 => bytes[c + 8..c + 16].copy_from_slice(&(h3 >> 16).to_le_bytes()),
                _ => bytes[c + 16..c + 24].copy_from_slice(&(h3 >> 16).to_le_bytes()),
            }
        }
    }
    bytes
}

// ≥ 1000 seeded mutations: decode never panics; it either reproduces
// the artifact bit-exactly (mutation hit padding, table order, or
// another don't-care byte) or returns an error.  A sample of every
// outcome also goes through the file loader, whose failures must be
// typed corruption errors.
#[test]
fn seeded_mutations_never_panic_and_errors_are_typed() {
    let base = serde::encode(&maximal_model());
    let path = tmp("mutant.gkm");
    for seed in 0..1200u64 {
        let mutated = mutate(&base, seed);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serde::decode(&mutated)
        }));
        let res = match res {
            Ok(r) => r,
            Err(_) => panic!("decode panicked on seed {seed}"),
        };
        if let Ok(m) = &res {
            assert_eq!(
                serde::encode(m),
                base,
                "seed {seed}: a materially-mutated artifact decoded successfully"
            );
        }
        if seed % 16 == 0 {
            // the same mutant through the file loader
            std::fs::write(&path, &mutated).unwrap();
            let loaded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                FittedModel::load(&path)
            }));
            match loaded {
                Err(_) => panic!("load panicked on seed {seed}"),
                Ok(Ok(m)) => {
                    assert!(res.is_ok(), "seed {seed}: load accepted what decode rejected");
                    assert_eq!(serde::encode(&m), base, "seed {seed}: lossy load");
                }
                Ok(Err(e)) => {
                    assert!(
                        e.is_corrupt() || e.to_string().contains("unsupported model version"),
                        "seed {seed}: load error is not typed corruption: {e}"
                    );
                }
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

// Deterministic per-section coverage: a bit flip in the middle of every
// section's payload (all kinds 1–9, CRC included) must be rejected, and
// through the file loader the rejection must carry `is_corrupt`.
#[test]
fn every_section_kind_rejects_a_payload_bit_flip() {
    let base = serde::encode(&maximal_model());
    let table = table_of(&base);
    assert!(
        table.len() >= 9,
        "maximal model must carry every section kind, found {}",
        table.len()
    );
    let path = tmp("flip.gkm");
    for &(kind, off, len) in &table {
        let mut bytes = base.clone();
        bytes[off + len / 2] ^= 0x10;
        let err = serde::decode(&bytes)
            .err()
            .unwrap_or_else(|| panic!("flip in section kind {kind} went undetected"));
        assert!(!err.is_empty());
        std::fs::write(&path, &bytes).unwrap();
        let err = FittedModel::load(&path)
            .err()
            .unwrap_or_else(|| panic!("load accepted flipped section kind {kind}"));
        assert!(err.is_corrupt(), "section kind {kind}: untyped error {err}");
    }
    std::fs::remove_file(&path).ok();
}

// Hostile u64 length fields must fail through the bounds-checked reader
// before any proportional allocation happens.  The CRC section is
// disabled first (kind zeroed in the table) so the length guards — not
// the checksum — are what reject the payloads.
#[test]
fn hostile_length_fields_fail_without_overallocating() {
    let base = serde::encode(&maximal_model());
    let table = table_of(&base);
    let crc_entry = table.iter().position(|&(k, _, _)| k == 6).unwrap();
    let mut no_crc = base.clone();
    no_crc[16 + 24 * crc_entry..16 + 24 * crc_entry + 4].copy_from_slice(&0u32.to_le_bytes());
    assert!(serde::decode(&no_crc).is_ok(), "zeroing the CRC entry must disable verification");

    // each target: (section kind, byte offset of a u64 length field
    // inside its payload)
    for &(kind, field_at) in &[
        (2u32, 0usize), // LABELS: label count
        (4, 0),         // GRAPH: n
        (4, 8),         // GRAPH: kappa
        (5, 0),         // VECTORS: rows
        (7, 0),         // QVECTORS: rows
        (8, 24),        // RTREE: nodes (after branch u32, beam u32, dim u64, k u64)
        (9, 0),         // DRIFT: baseline count
    ] {
        let (_, off, _) = *table.iter().find(|&&(k, _, _)| k == kind).unwrap();
        for hostile in [u64::MAX, 1 << 61, 1 << 40] {
            let mut bytes = no_crc.clone();
            bytes[off + field_at..off + field_at + 8].copy_from_slice(&hostile.to_le_bytes());
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                serde::decode(&bytes)
            }))
            .unwrap_or_else(|_| panic!("kind {kind} length {hostile:#x} panicked"));
            assert!(res.is_err(), "kind {kind} length {hostile:#x} was accepted");
        }
    }
}

// Folded from the old ad-hoc corruption test: blunt truncations and a
// missing file.  Truncation is typed corruption; a missing file is a
// plain I/O error, not corruption.
#[test]
fn truncations_and_missing_files_are_rejected() {
    let base = serde::encode(&maximal_model());
    let path = tmp("trunc.gkm");
    for cut in [base.len() / 2, base.len() - 1, 16 + 24, 16, 12, 8, 0] {
        std::fs::write(&path, &base[..cut]).unwrap();
        let err = FittedModel::load(&path)
            .err()
            .unwrap_or_else(|| panic!("truncation to {cut} bytes went undetected"));
        assert!(err.is_corrupt(), "truncation to {cut}: untyped error {err}");
        assert!(serde::decode(&base[..cut]).is_err());
    }
    std::fs::remove_file(&path).ok();
    let err = FittedModel::load(std::path::Path::new("/definitely/not/here.gkm")).unwrap_err();
    assert!(!err.is_corrupt(), "a missing file is I/O, not corruption: {err}");
}

// The unmutated maximal artifact round-trips every section bit-exactly
// (the fuzz baseline must itself be sound).
#[test]
fn maximal_artifact_roundtrips_bit_exact() {
    let m = maximal_model();
    let bytes = serde::encode(&m);
    let back = serde::decode(&bytes).unwrap();
    assert_eq!(serde::encode(&back), bytes);
    assert_eq!(back.labels, m.labels);
    assert!(back.graph.is_some() && back.quantized.is_some());
    assert!(back.route.is_some() && back.drift.is_some());
    let (bd, md) = (back.drift.as_ref().unwrap(), m.drift.as_ref().unwrap());
    for (a, b) in bd.baseline.iter().zip(&md.baseline) {
        assert_eq!(a.to_bits(), b.to_bits(), "NaN baselines must round-trip bitwise");
    }
}
