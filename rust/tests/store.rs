//! Integration tests for the `VecStore` storage layer: the
//! ChunkedVecStore ↔ VecSet equivalence property, the GKMODEL v1 → v2
//! migration contract (against a committed byte fixture), the
//! out-of-core serving path (`predict_batch` / `search_batch` from a v2
//! artifact with vectors paged from disk through a deliberately tiny
//! block cache), and the locality-aware scan planner: a `CountingStore`
//! wrapper instruments chunk reads to assert that super-block-planned
//! GK-means epochs touch disk like a sequential scan while the global
//! shuffle degenerates to ~one read per sample, plus quality parity and
//! the streaming Boost/Closure fits.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use gkmeans::data::matrix::VecSet;
use gkmeans::data::plan::ScanOrder;
use gkmeans::data::store::{self, ChunkedVecStore, VecStore};
use gkmeans::gkm::ann::SearchParams;
use gkmeans::model::{
    Boost, ClosureKmeans, Clusterer, FittedModel, GkMeans, ModelVectors, RunContext,
};
use gkmeans::runtime::Backend;
use gkmeans::testing::prop;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gkm_store_it_{}_{name}", std::process::id()))
}

fn write_flat(path: &Path, v: &VecSet) {
    let mut bytes = Vec::with_capacity(v.flat().len() * 4);
    for &x in v.flat() {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).unwrap();
}

#[test]
fn prop_chunked_store_matches_vecset_on_random_access() {
    // The storage-equivalence property: over random chunk geometries,
    // cache budgets, and access patterns (single rows, blocks, row
    // pairs), a ChunkedVecStore returns bit-identical data to the
    // in-RAM VecSet it was written from.
    prop::check("chunked store ≡ VecSet", 12, |g| {
        let n = g.usize_in(1, 400);
        let d = g.usize_in(1, 24);
        let data = g.matrix(n, d, 3.0);
        let path = tmp(&format!("prop_{n}_{d}.bin"));
        write_flat(&path, &data);
        let chunk_rows = g.usize_in(1, n + 3);
        let cache = g.usize_in(2, 6);
        let store = ChunkedVecStore::open_flat(&path, d)
            .map_err(|e| e.to_string())?
            .chunk_rows(chunk_rows)
            .cache_chunks(cache);
        if VecStore::rows(&store) != n || VecStore::dim(&store) != d {
            std::fs::remove_file(&path).ok();
            return Err(format!(
                "shape mismatch: {}x{} vs {n}x{d}",
                VecStore::rows(&store),
                VecStore::dim(&store)
            ));
        }
        let mut cur = store.open();
        for _ in 0..200 {
            match g.usize_in(0, 2) {
                0 => {
                    let i = g.usize_in(0, n - 1);
                    if cur.row(i) != data.row(i) {
                        std::fs::remove_file(&path).ok();
                        return Err(format!("row {i} mismatch (chunk_rows={chunk_rows})"));
                    }
                }
                1 => {
                    let lo = g.usize_in(0, n - 1);
                    let hi = g.usize_in(lo + 1, n);
                    if cur.block(lo, hi) != data.rows_flat(lo, hi) {
                        std::fs::remove_file(&path).ok();
                        return Err(format!("block [{lo},{hi}) mismatch"));
                    }
                }
                _ => {
                    let i = g.usize_in(0, n - 1);
                    let j = g.usize_in(0, n - 1);
                    let want = gkmeans::core_ops::dist::d2(data.row(i), data.row(j));
                    if cur.d2_pair(i, j).to_bits() != want.to_bits() {
                        std::fs::remove_file(&path).ok();
                        return Err(format!("d2_pair({i},{j}) not bit-identical"));
                    }
                }
            }
        }
        std::fs::remove_file(&path).ok();
        Ok(())
    });
}

#[test]
fn materialize_and_gather_agree_with_ram() {
    let data = gkmeans::data::synth::sift_like(300, 9);
    let path = tmp("gather.bin");
    write_flat(&path, &data);
    let chunked =
        ChunkedVecStore::open_flat(&path, data.dim()).unwrap().chunk_rows(17).cache_chunks(2);
    assert_eq!(store::materialize(&chunked), data);
    let idx = [299usize, 0, 150, 150, 7];
    assert_eq!(store::gather(&chunked, &idx), data.gather(&idx));
    std::fs::remove_file(&path).ok();
}

fn assert_models_bit_identical(a: &FittedModel, b: &FittedModel) {
    assert_eq!(a.method, b.method);
    assert_eq!(a.k, b.k);
    assert_eq!(a.dim, b.dim);
    assert_eq!(a.n_train, b.n_train);
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.history.len(), b.history.len());
    assert_eq!(a.total_seconds.to_bits(), b.total_seconds.to_bits());
    for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert_eq!(a.graph.is_some(), b.graph.is_some());
    if let (Some(ga), Some(gb)) = (&a.graph, &b.graph) {
        assert_eq!(ga.ids_flat(), gb.ids_flat());
        for (x, y) in ga.dists_flat().iter().zip(gb.dists_flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    assert_eq!(a.data.is_some(), b.data.is_some());
    if let (Some(da), Some(db)) = (&a.data, &b.data) {
        let (da, db) = (da.to_vecset(), db.to_vecset());
        assert_eq!(da.flat().len(), db.flat().len());
        for (x, y) in da.flat().iter().zip(db.flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

#[test]
fn committed_v1_fixture_loads_and_migrates_to_v2_bit_exact() {
    // The fixture bytes were written by the v1 encoder and are committed
    // so the legacy-format contract outlives the code that wrote it.
    let fixture = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/model_v1_fixture.gkm");
    let v1 = FittedModel::load(&fixture).expect("v1 fixture must load");
    assert_eq!(v1.method, gkmeans::coordinator::job::Method::GkMeans);
    assert_eq!((v1.k, v1.dim, v1.n_train), (2, 2, 4));
    assert_eq!(v1.labels, vec![0, 0, 0, 1]);
    assert_eq!(v1.history.len(), 1);
    let graph = v1.graph.as_ref().expect("fixture embeds a graph");
    assert_eq!(graph.neighbors(0), &[1, 2]);
    assert_eq!(graph.neighbors(3), &[1, 2]);
    let data = v1.data.as_ref().expect("fixture embeds vectors");
    assert!(data.is_resident(), "v1 vectors are embedded");
    assert_eq!(data.fetch_row(3), vec![5.0, 5.0]);

    // v1 → save-as-v2 → load round-trips bit-exact, with lazy vectors
    let out = tmp("migrated_fixture.gkm");
    v1.save(&out).unwrap();
    let v2 = FittedModel::load(&out).unwrap();
    assert!(!v2.data.as_ref().unwrap().is_resident(), "v2 load pages vectors");
    assert_models_bit_identical(&v1, &v2);
    // the migrated artifact still answers queries
    assert_eq!(v2.predict(&VecSet::from_flat(2, vec![4.9, 5.1]))[0], 1);
    std::fs::remove_file(&out).ok();
}

/// Fit a small graph model with embedded vectors (the serving shape).
fn serving_model(n: usize) -> (VecSet, FittedModel) {
    let data = gkmeans::data::synth::sift_like(n, 4242);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(4).keep_data(true);
    let model = GkMeans::new((n / 40).max(2)).kappa(8).tau(3).xi(30).fit(&data, &ctx);
    (data, model)
}

/// Shrink a lazily-loaded model's block cache to a deliberately tiny
/// budget so the test exercises real eviction, not one warm chunk.
fn starve_cache(model: &mut FittedModel) {
    let data = model.data.take().expect("model has vectors");
    let disk = match data {
        ModelVectors::Disk(c) => c,
        ModelVectors::Ram(_) => panic!("expected paged vectors"),
    };
    model.data = Some(ModelVectors::Disk(disk.chunk_rows(8).cache_chunks(2)));
}

#[test]
fn out_of_core_predict_batch_matches_in_ram() {
    let (data, model) = serving_model(500);
    let path = tmp("ooc_predict.gkm");
    model.save(&path).unwrap();
    let mut served = FittedModel::load(&path).unwrap();
    starve_cache(&mut served);

    let queries = gkmeans::data::synth::sift_like(200, 777);
    let want = model.predict(&queries);
    // in-RAM batch == in-RAM predict
    assert_eq!(model.predict_batch(&queries), want);
    // the reloaded artifact (eager centroids, paged vectors) agrees
    assert_eq!(served.predict(&queries), want);
    assert_eq!(served.predict_batch(&queries), want);
    // threaded batch identical
    served.threads = 4;
    assert_eq!(served.predict_batch(&queries), want);
    // and a disk-backed *query* store streams to the same labels
    let qpath = tmp("ooc_queries.bin");
    write_flat(&qpath, &queries);
    let qstore =
        ChunkedVecStore::open_flat(&qpath, queries.dim()).unwrap().chunk_rows(16).cache_chunks(2);
    assert_eq!(served.predict_batch(&qstore), want);
    assert_eq!(data.rows(), 500);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&qpath).ok();
}

#[test]
fn out_of_core_search_batch_matches_single_queries() {
    let (data, model) = serving_model(600);
    let path = tmp("ooc_search.gkm");
    model.save(&path).unwrap();
    let mut served = FittedModel::load(&path).unwrap();
    starve_cache(&mut served);
    served.threads = 3;

    let sp = SearchParams { ef: 32, entries: 16, seed: 11 };
    let nq = 40;
    let mut qflat = Vec::with_capacity(nq * data.dim());
    for i in 0..nq {
        qflat.extend(data.row(i * 7).iter().map(|v| v + 0.01));
    }
    let queries = VecSet::from_flat(data.dim(), qflat);

    // batched multi-threaded search over paged vectors == repeated
    // single searches over the embedded in-RAM vectors
    let batched = served.search_batch(&queries, 5, &sp).unwrap();
    assert_eq!(batched.len(), nq);
    for (i, got) in batched.iter().enumerate() {
        let single = model.search(queries.row(i), 5, &sp).unwrap();
        assert_eq!(got, &single, "query {i}");
        let served_single = served.search(queries.row(i), 5, &sp).unwrap();
        assert_eq!(got, &served_single, "query {i} (served single)");
    }
    std::fs::remove_file(&path).ok();
}

/// A [`VecStore`] wrapper with an instrumented chunk-read counter: every
/// chunk its cursors page in from disk bumps the shared counter, so the
/// locality assertions below are phrased directly in "chunks read".
struct CountingStore {
    inner: ChunkedVecStore,
    reads: Arc<AtomicU64>,
}

impl CountingStore {
    fn new(store: ChunkedVecStore) -> CountingStore {
        let reads = Arc::new(AtomicU64::new(0));
        CountingStore { inner: store.with_read_counter(reads.clone()), reads }
    }

    fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.reads.store(0, Ordering::Relaxed)
    }
}

impl VecStore for CountingStore {
    fn rows(&self) -> usize {
        VecStore::rows(&self.inner)
    }

    fn dim(&self) -> usize {
        VecStore::dim(&self.inner)
    }

    fn open(&self) -> gkmeans::data::store::StoreCursor<'_> {
        self.inner.open()
    }

    fn disk_backing(&self) -> Option<&ChunkedVecStore> {
        Some(&self.inner)
    }

    fn scan_geometry(&self) -> Option<gkmeans::data::plan::ScanGeometry> {
        VecStore::scan_geometry(&self.inner)
    }
}

#[test]
fn superblock_gkmeans_epochs_read_5x_fewer_chunks_than_global() {
    use gkmeans::data::synth::{blobs, BlobSpec};
    use gkmeans::gkm::gkmeans as gk;
    use gkmeans::kmeans::common::{Clustering, KmeansParams};

    // 600 rows at 8 rows/chunk = 75 chunks; the cursor cache holds 8 of
    // them (~11%, well under the 25% bound), so a globally shuffled
    // epoch misses on nearly every row while the super-block order pages
    // each chunk once per epoch.
    let data = blobs(&BlobSpec { sigma: 0.5, ..BlobSpec::quick(600, 8, 12) }, 31);
    let path = tmp("locality.bin");
    write_flat(&path, &data);
    let graph = gkmeans::graph::brute::build(&data, 8, &Backend::native());
    let init = gkmeans::kmeans::two_means::run(
        &data,
        12,
        &gkmeans::kmeans::two_means::TwoMeansParams::default(),
        &Backend::native(),
    );

    let mut results = Vec::new();
    for order in [ScanOrder::Global, ScanOrder::Superblock] {
        let store = CountingStore::new(
            ChunkedVecStore::open_flat(&path, data.dim()).unwrap().chunk_rows(8).cache_chunks(8),
        );
        let clustering = Clustering::from_labels(&store, init.clone(), 12);
        store.reset(); // count only the optimization scans
        let params = gk::GkMeansParams {
            kappa: 8,
            base: KmeansParams {
                max_iters: 10,
                min_move_rate: 0.0,
                seed: 2,
                threads: 1,
                scan_order: order,
            },
        };
        let out = gk::run_from(&store, clustering, &graph, &params);
        assert_eq!(out.history.len(), 11, "all 10 epochs must run ({order:?})");
        results.push((store.reads(), out.distortion()));
    }
    let (global_reads, global_distortion) = results[0];
    let (sb_reads, sb_distortion) = results[1];
    assert!(sb_reads > 0);
    assert!(
        global_reads >= 5 * sb_reads,
        "expected >=5x fewer chunk reads: global={global_reads} superblock={sb_reads}"
    );
    // quality parity: same init, same graph — final distortion within 2%
    assert!(
        (sb_distortion - global_distortion).abs() <= 0.02 * global_distortion.abs() + 1e-9,
        "distortion diverged: global={global_distortion} superblock={sb_distortion}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn resident_fit_is_bit_identical_for_every_scan_order() {
    // On resident data the planner resolves every policy to the global
    // shuffle, so the knob cannot change results — planning off keeps
    // the historical fits bit-for-bit.
    let data = gkmeans::data::synth::sift_like(300, 55);
    let backend = Backend::native();
    let cfg = GkMeans::new(6).kappa(6).tau(2).xi(30);
    let base = cfg.fit(&data, &RunContext::new(&backend).max_iters(4));
    for order in [ScanOrder::Auto, ScanOrder::Global, ScanOrder::Superblock] {
        let m = cfg.fit(&data, &RunContext::new(&backend).max_iters(4).scan_order(order));
        assert_eq!(m.labels, base.labels, "{order:?}");
        for (a, b) in m.centroids.flat().iter().zip(base.centroids.flat()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{order:?}");
        }
    }
}

#[test]
fn boost_and_closure_stream_out_of_core() {
    // PR 3 left Boost and Closure materializing a resident copy inside
    // fit_store; they now stream through planned cursors.  Under the
    // global order the streamed fit is bit-identical to the resident
    // fit; under the default (auto -> superblock) order it still
    // converges to the same quality class.
    let data = gkmeans::data::synth::sift_like(300, 77);
    let path = tmp("stream_bc.bin");
    write_flat(&path, &data);
    let chunked =
        ChunkedVecStore::open_flat(&path, data.dim()).unwrap().chunk_rows(16).cache_chunks(2);
    let backend = Backend::native();

    let configs: Vec<Box<dyn Clusterer>> =
        vec![Box::new(Boost::new(6)), Box::new(ClosureKmeans::new(6).trees(2))];
    for cfg in &configs {
        let resident = cfg.fit(&data, &RunContext::new(&backend).max_iters(5));
        let streamed = cfg.fit_store(
            &chunked,
            &RunContext::new(&backend).max_iters(5).scan_order(ScanOrder::Global),
        );
        assert_eq!(resident.labels, streamed.labels, "{}", cfg.name());
        for (a, b) in resident.centroids.flat().iter().zip(streamed.centroids.flat()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}", cfg.name());
        }
        // the planned (super-block) order reaches comparable quality
        let planned = cfg.fit_store(&chunked, &RunContext::new(&backend).max_iters(5));
        assert!(planned.distortion().is_finite(), "{}", cfg.name());
        assert!(
            planned.distortion() <= resident.distortion() * 1.15 + 1e-9,
            "{}: planned {} vs resident {}",
            cfg.name(),
            planned.distortion(),
            resident.distortion()
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn out_of_core_fit_matches_in_ram_fit() {
    // Clustering a disk-backed dataset (GK-means end to end, graph build
    // included) under `--scan-order global` must reproduce the in-RAM
    // fit bit-for-bit at threads=1: the cursors feed the same bytes
    // through the same kernels in the same order.  (The default `auto`
    // order plans chunk-aligned super-blocks on a paged store — same
    // quality class, different visit order; see the locality tests.)
    let data = gkmeans::data::synth::sift_like(400, 99);
    let path = tmp("ooc_fit.bin");
    write_flat(&path, &data);
    let chunked =
        ChunkedVecStore::open_flat(&path, data.dim()).unwrap().chunk_rows(32).cache_chunks(3);

    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(3).keep_data(true);
    let ctx_global = RunContext::new(&backend)
        .max_iters(3)
        .keep_data(true)
        .scan_order(ScanOrder::Global);
    let cfg = GkMeans::new(8).kappa(6).tau(2).xi(30);
    let in_ram = cfg.fit(&data, &ctx);
    let streamed = cfg.fit_store(&chunked, &ctx_global);

    assert_eq!(in_ram.labels, streamed.labels);
    for (a, b) in in_ram.centroids.flat().iter().zip(streamed.centroids.flat()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // keep_data on a streamed fit keeps the disk handle, not a RAM copy
    assert!(!streamed.data.as_ref().unwrap().is_resident());
    // ... and saving it embeds the same bytes the RAM fit embeds
    let out = tmp("ooc_fit.gkm");
    streamed.save(&out).unwrap();
    let back = FittedModel::load(&out).unwrap();
    assert_eq!(back.data.as_ref().unwrap().to_vecset(), data);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&out).ok();
}
