//! Integration tests for the incremental layer (`FittedModel::extend`):
//! the batch ≡ row-by-row determinism contract, artifact round trips of
//! a grown index, repaired-graph quality against from-scratch brute
//! force, fit+extend clustering quality against a full refit, and
//! fault-injected extends over a flaky store.

use gkmeans::data::matrix::VecSet;
use gkmeans::data::store::{ChunkedVecStore, FaultPolicy};
use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::eval::cooccur;
use gkmeans::gkm::ann::SearchParams;
use gkmeans::graph::{brute, recall};
use gkmeans::model::{serde, Clusterer, ExtendParams, FittedModel, GkMeans, RunContext};
use gkmeans::runtime::Backend;
use gkmeans::testing::fault::{FaultPlan, FaultStore};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gkm_extend_{}_{name}", std::process::id()))
}

/// Split a dataset's rows into `[0, n0)` and `[n0, n)`.
fn split(data: &VecSet, n0: usize) -> (VecSet, VecSet) {
    let d = data.dim();
    let old = VecSet::from_flat(d, data.flat()[..n0 * d].to_vec());
    let new = VecSet::from_flat(d, data.flat()[n0 * d..].to_vec());
    (old, new)
}

fn fit(data: &VecSet, k: usize, kappa: usize) -> FittedModel {
    let b = Backend::native();
    let ctx = RunContext::new(&b).threads(1).max_iters(4).keep_data(true);
    GkMeans::new(k).kappa(kappa).tau(3).xi(25).fit(data, &ctx)
}

// The determinism contract: with refinement off, one m-row extend and m
// one-row extends must leave bit-identical models — same labels, same
// graph after repair, same serialized artifact.
#[test]
fn batch_extend_equals_row_by_row_bitwise() {
    let all = blobs(&BlobSpec::quick(280, 6, 4), 101);
    let (old, new) = split(&all, 200);
    let base = fit(&old, 4, 6);

    let mut batch = base.clone();
    let report = batch.extend(&new).unwrap();
    assert_eq!(report.added, 80);

    let mut serial = base;
    let mut serial_updates = 0usize;
    for i in 0..new.rows() {
        let one = VecSet::from_flat(new.dim(), new.row(i).to_vec());
        serial_updates += serial.extend(&one).unwrap().graph_updates;
    }

    assert_eq!(batch.labels, serial.labels, "assignments must agree");
    assert_eq!(
        report.graph_updates, serial_updates,
        "repair must apply the identical update sequence"
    );
    let (bg, sg) = (batch.graph.as_ref().unwrap(), serial.graph.as_ref().unwrap());
    assert_eq!(bg.ids_flat(), sg.ids_flat(), "graphs must agree after repair");
    for (a, b) in bg.dists_flat().iter().zip(sg.dists_flat()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    bg.check_invariants().unwrap();
    assert_eq!(
        serde::encode(&batch),
        serde::encode(&serial),
        "batch and row-by-row extends must serialize bit-identically"
    );
}

// Extend → save → load → save round-trips bit-exact, including the SQ8
// codes the extend appended with the fit-time quantizer.
#[test]
fn extend_save_load_roundtrips_bit_exact() {
    let all = blobs(&BlobSpec::quick(300, 5, 4), 103);
    let (old, new) = split(&all, 240);
    let mut model = fit(&old, 4, 6);
    model.quantize_sq8(0).unwrap();
    model.extend(&new).unwrap();
    assert_eq!(model.quantized.as_ref().unwrap().rows(), 300);

    let (p1, p2) = (tmp("rt1.gkm"), tmp("rt2.gkm"));
    model.save(&p1).unwrap();
    let loaded = FittedModel::load(&p1).unwrap();
    loaded.save(&p2).unwrap();
    let (b1, b2) = (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    assert_eq!(b1, b2, "save → load → save must be bit-exact");

    assert_eq!(loaded.n_train, 300);
    assert_eq!(loaded.labels, model.labels);
    let sp = SearchParams { ef: 48, entries: 24, seed: 7 };
    for qi in [0usize, 250, 299] {
        assert_eq!(
            model.search(all.row(qi), 5, &sp).unwrap(),
            loaded.search(all.row(qi), 5, &sp).unwrap(),
            "query {qi}"
        );
    }
}

// Acceptance floor: after an extend, the graph/routed ANN search finds
// each appended row (queried exactly) with recall ≥ 0.9.
#[test]
fn post_extend_search_recall_on_new_rows() {
    let all = blobs(&BlobSpec::quick(420, 6, 5), 107);
    let (old, new) = split(&all, 360);
    let mut model = fit(&old, 5, 8);
    model.extend(&new).unwrap();

    let sp = SearchParams { ef: 96, entries: 64, seed: 5 };
    let mut hits = 0usize;
    for i in 0..new.rows() {
        let res = model.search(new.row(i), 1, &sp).unwrap();
        if res.first().map(|r| r.1) == Some((360 + i) as u32) {
            hits += 1;
        }
    }
    let recall = hits as f64 / new.rows() as f64;
    assert!(
        recall >= 0.9,
        "post-extend search recall on new rows {recall} below the 0.9 floor"
    );
}

// Localized repair quality: starting from an exact base graph, the
// repaired graph over the union must keep top-1 recall ≥ 0.9 of a
// from-scratch brute-force graph over the union.
#[test]
fn repaired_graph_recall_vs_from_scratch_brute_force() {
    let b = Backend::native();
    let all = blobs(&BlobSpec::quick(360, 6, 4), 109);
    let (old, new) = split(&all, 300);
    let mut model = fit(&old, 4, 8);
    // isolate the repair: the base graph is exact, so recall lost below
    // is attributable to the localized joins alone
    model.graph = Some(brute::build(&old, 8, &b));
    model.extend(&new).unwrap();

    let repaired = model.graph.as_ref().unwrap();
    assert_eq!(repaired.n(), 360);
    repaired.check_invariants().unwrap();
    let exact = brute::build(&all, 8, &b);
    let r = recall::recall_at_1(repaired, &exact);
    assert!(
        r >= 0.9,
        "repaired graph recall@1 {r} below 0.9 of the from-scratch graph"
    );
}

// fit(n) + extend(m) with the drift trigger must land within a pinned
// tolerance of fit(n+m) on clustered data, measured by KNN label
// co-occurrence against the exact graph over the union (the paper's
// quality proxy).
#[test]
fn fit_plus_extend_tracks_full_fit_quality() {
    let b = Backend::native();
    let all = blobs(&BlobSpec::quick(500, 6, 5), 113);
    let (old, new) = split(&all, 400);

    let mut inc = fit(&old, 5, 8);
    let params = ExtendParams { refine_drift: Some(0.1), ..Default::default() };
    inc.extend_with(&new, &params).unwrap();
    let full = fit(&all, 5, 8);

    let exact = brute::build(&all, 10, &b);
    let mean = |labels: &[u32]| {
        let series = cooccur::cooccurrence_by_rank(&exact, labels, 10);
        series.iter().sum::<f64>() / series.len() as f64
    };
    let q_inc = mean(&inc.labels);
    let q_full = mean(&full.labels);
    let random = cooccur::random_collision_rate(&inc.labels, inc.k);
    assert!(
        q_inc > random + 0.2,
        "incremental co-occurrence {q_inc} barely above random {random}"
    );
    assert!(
        q_inc >= q_full - 0.15,
        "fit+extend co-occurrence {q_inc} more than 0.15 below full fit {q_full}"
    );
}

// A transiently-faulty store (with a retry budget) must produce the
// bitwise-identical extend a fault-free store does: retries re-read the
// same bytes and the repair path is deterministic.
#[test]
fn transient_fault_extend_is_bit_identical() {
    let all = blobs(&BlobSpec::quick(260, 6, 4), 127);
    let (old, new) = split(&all, 200);
    let base = fit(&old, 4, 6);

    let p = tmp("transient.fvecs");
    gkmeans::data::io::write_fvecs(&p, &new).unwrap();
    let open = || ChunkedVecStore::open_fvecs(&p).unwrap().chunk_rows(8).cache_chunks(2);

    let mut want = base.clone();
    want.extend(&new).unwrap();

    let faulty = FaultStore::new(
        open(),
        FaultPlan::transient(42, 0.1),
        FaultPolicy { retries: 12, backoff: std::time::Duration::ZERO },
    );
    let mut got = base;
    got.extend(&faulty).unwrap();
    std::fs::remove_file(&p).ok();

    assert!(faulty.injected() > 0, "rate 0.1 over {} ops injected nothing", faulty.ops());
    assert_eq!(
        serde::encode(&got),
        serde::encode(&want),
        "transient-fault extend must be bitwise identical to the fault-free extend"
    );
}

// A store that dies mid-extend surfaces a typed error, leaves the
// in-RAM model untouched, and leaves the on-disk artifact loadable at
// its pre-extend state.
#[test]
fn permanent_fault_mid_extend_leaves_artifact_at_pre_extend_state() {
    let all = blobs(&BlobSpec::quick(260, 6, 4), 131);
    let (old, new) = split(&all, 200);
    let mut model = fit(&old, 4, 6);

    let path = tmp("pre_extend.gkm");
    model.save(&path).unwrap();
    let disk_before = std::fs::read(&path).unwrap();
    let ram_before = serde::encode(&model);

    let p = tmp("dying.fvecs");
    gkmeans::data::io::write_fvecs(&p, &new).unwrap();
    let dying = FaultStore::new(
        ChunkedVecStore::open_fvecs(&p).unwrap().chunk_rows(8).cache_chunks(2),
        FaultPlan::dies_at(0, 3),
        FaultPolicy::none(),
    );
    let err = model.extend(&dying).unwrap_err();
    std::fs::remove_file(&p).ok();
    assert!(dying.injected() > 0, "the permanent fault never fired");
    assert!(
        err.to_string().contains("reading new row"),
        "extend must surface the store fault as a typed error: {err}"
    );

    // nothing mutated in RAM …
    assert_eq!(model.n_train, 200);
    assert_eq!(serde::encode(&model), ram_before, "a failed extend must not mutate the model");
    // … and the artifact still loads, bit-for-bit at its pre-extend state
    assert_eq!(std::fs::read(&path).unwrap(), disk_before);
    let loaded = FittedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.n_train, 200);
    assert_eq!(loaded.labels, model.labels);
}
