//! Integration tests for the fit → model → query surface: `Clusterer`
//! configs, `FittedModel` predict/search, the versioned binary artifact
//! round trip, and the deprecated-shim compatibility contract.

use gkmeans::data::matrix::VecSet;
use gkmeans::data::synth::{blobs, sift_like, BlobSpec};
use gkmeans::gkm::ann::SearchParams;
use gkmeans::model::{Clusterer, FittedModel, GkMeans, KGraphGkMeans, Lloyd, RunContext};
use gkmeans::runtime::Backend;
use gkmeans::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gkm_model_api_{}_{name}", std::process::id()))
}

#[test]
fn save_load_predict_roundtrip_is_bit_identical() {
    let data = blobs(&BlobSpec::quick(600, 8, 6), 11);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(6).keep_data(true);
    let model = GkMeans::new(6).kappa(8).tau(3).xi(30).fit(&data, &ctx);

    let path = tmp("roundtrip.gkm");
    model.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // every persisted buffer round-trips bitwise
    assert_eq!(loaded.method, model.method);
    assert_eq!(loaded.labels, model.labels);
    for (a, b) in loaded.centroids.flat().iter().zip(model.centroids.flat()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let (lg, mg) = (loaded.graph.as_ref().unwrap(), model.graph.as_ref().unwrap());
    assert_eq!(lg.ids_flat(), mg.ids_flat());
    for (a, b) in lg.dists_flat().iter().zip(mg.dists_flat()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // … so out-of-sample predict is bit-identical across the round trip
    let queries = blobs(&BlobSpec::quick(300, 8, 6), 12);
    assert_eq!(model.predict(&queries), loaded.predict(&queries));

    // … and so is search, served purely from the loaded artifact
    let sp = SearchParams { entries: 32, ..Default::default() };
    let q = data.row(17);
    assert_eq!(
        model.search(q, 5, &sp).unwrap(),
        loaded.search(q, 5, &sp).unwrap()
    );
}

#[test]
fn predict_matches_brute_force_nearest_centroid() {
    let data = blobs(&BlobSpec::quick(400, 6, 5), 21);
    let backend = Backend::native();
    let model = Lloyd::new(5).fit(&data, &RunContext::new(&backend).max_iters(8));
    // out-of-sample queries from the same distribution
    let queries = blobs(&BlobSpec::quick(200, 6, 5), 22);
    let preds = model.predict(&queries);
    assert_eq!(preds.len(), 200);
    for (i, &p) in preds.iter().enumerate() {
        let q = queries.row(i);
        let chosen = gkmeans::core_ops::dist::d2(q, model.centroids.row(p as usize));
        let best = (0..model.k)
            .map(|r| gkmeans::core_ops::dist::d2(q, model.centroids.row(r)))
            .fold(f32::INFINITY, f32::min);
        // blocked-kernel assignment may differ from the scalar path only
        // at fp tie-break level
        assert!(
            chosen <= best + 1e-4 * (1.0 + best),
            "query {i}: predicted centroid at {chosen}, brute best {best}"
        );
    }
}

#[test]
fn predict_respects_thread_count() {
    let data = sift_like(1_200, 5);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(4);
    let mut model = KGraphGkMeans::new(12).kappa(8).fit(&data, &ctx);
    let serial = model.predict(&data);
    for threads in [2usize, 4, 0] {
        model.threads = threads;
        assert_eq!(model.predict(&data), serial, "threads={threads}");
    }
}

#[test]
fn search_recall_beats_floor_at_kappa_10() {
    let n = 1_500;
    let data = sift_like(n, 31);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(3).keep_data(true);
    let model = GkMeans::new((n / 50).max(2)).kappa(10).tau(8).fit(&data, &ctx);

    let mut rng = Rng::new(77);
    let sp = SearchParams { ef: 64, entries: 48, seed: 3 };
    let nq = 100;
    let mut hits = 0usize;
    for _ in 0..nq {
        let qi = rng.below(n);
        let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.001).collect();
        // tiny perturbation: the true nearest neighbor is qi itself
        let res = model.search(&q, 1, &sp).unwrap();
        if res.first().map(|r| r.1) == Some(qi as u32) {
            hits += 1;
        }
    }
    let recall = hits as f64 / nq as f64;
    assert!(
        recall >= 0.6,
        "graph ANN recall@1 {recall} below the 0.6 floor at kappa=10"
    );
}

#[test]
fn sq8_search_recall_is_within_one_percent_of_f32() {
    let n = 1_500;
    let data = sift_like(n, 31);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(3).keep_data(true);
    let mut model = GkMeans::new((n / 50).max(2)).kappa(10).tau(8).fit(&data, &ctx);

    let sp = SearchParams { ef: 64, entries: 48, seed: 3 };
    let nq = 100;
    let recall_of = |m: &FittedModel| {
        let mut rng = Rng::new(77);
        let mut hits = 0usize;
        for _ in 0..nq {
            let qi = rng.below(n);
            let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.001).collect();
            let res = m.search(&q, 1, &sp).unwrap();
            if res.first().map(|r| r.1) == Some(qi as u32) {
                hits += 1;
            }
        }
        hits as f64 / nq as f64
    };
    let exact = recall_of(&model);
    model.quantize_sq8(0).unwrap();
    assert!(model.quantized.is_some());
    // traversal now runs over u8 codes; the exact re-rank of the ef pool
    // must absorb the quantization error at the top of the result list
    let quant = recall_of(&model);
    assert!(
        quant >= exact - 0.01,
        "sq8 recall {quant} fell more than 1% below the f32 recall {exact}"
    );
    assert!(quant >= 0.6, "sq8 recall {quant} below the 0.6 floor");
}

#[test]
fn quantized_artifact_roundtrips_and_serves_identically() {
    let data = sift_like(400, 71);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(3).keep_data(true);
    let mut model = GkMeans::new(8).kappa(8).tau(3).fit(&data, &ctx);
    model.quantize_sq8(64).unwrap();

    let path = tmp("sq8_roundtrip.gkm");
    model.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let (mq, lq) = (model.quantized.as_ref().unwrap(), loaded.quantized.as_ref().unwrap());
    assert_eq!(mq.codes(), lq.codes());
    assert_eq!(mq.quantizer(), lq.quantizer());
    // the loaded artifact pages its f32 vectors from disk while the codes
    // stay resident; search must serve identical results either way
    // (traversal over identical codes, re-rank over bit-identical rows)
    assert!(!loaded.data.as_ref().unwrap().is_resident());
    let sp = SearchParams { ef: 32, entries: 16, seed: 9 };
    for qi in [0usize, 57, 201, 399] {
        let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.0005).collect();
        assert_eq!(
            model.search(&q, 5, &sp).unwrap(),
            loaded.search(&q, 5, &sp).unwrap(),
            "query {qi}"
        );
    }
}

// The old free-function API must keep old call sites compiling and
// produce the same numbers the trait surface does (threads=1 paths are
// deterministic).
#[test]
#[allow(deprecated)]
fn deprecated_shims_still_compile_and_agree_with_the_new_surface() {
    let data = blobs(&BlobSpec::quick(300, 5, 4), 41);
    let backend = Backend::native();
    let params = gkmeans::kmeans::common::KmeansParams::default();

    let old = gkmeans::kmeans::lloyd::run(&data, 4, &params, &backend);
    let new = Lloyd::new(4).fit(&data, &RunContext::new(&backend));
    assert_eq!(old.clustering.labels, new.labels);

    let graph = gkmeans::graph::brute::build(&data, 8, &backend);
    let gparams = gkmeans::gkm::gkmeans::GkMeansParams { kappa: 8, base: params };
    let old_gk = gkmeans::gkm::gkmeans::run(&data, 4, &graph, &gparams, &backend);
    assert_eq!(old_gk.clustering.labels.len(), 300);
    let old_star = gkmeans::gkm::variant::run(&data, 4, &graph, &gparams, &backend);
    assert_eq!(old_star.clustering.labels.len(), 300);
    let old_e2e = gkmeans::gkm::cluster(&data, 4, &gparams, &backend);
    assert!(old_e2e.distortion().is_finite());
}

// Corruption rejection now lives in `tests/fuzz_model.rs`, which fuzzes
// every section kind with seeded mutations instead of one truncation.

#[test]
fn keep_data_embeds_the_training_vectors() {
    let data = blobs(&BlobSpec::quick(150, 4, 3), 61);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(3).keep_data(true);
    let model = GkMeans::new(3).kappa(5).tau(2).fit(&data, &ctx);
    let embedded = model.data.as_ref().unwrap();
    assert!(embedded.is_resident(), "in-RAM fit keeps vectors resident");
    assert_eq!(embedded.rows(), 150);
    assert_eq!(embedded.as_ram().unwrap().flat(), data.flat());
    // predict on a dimension mismatch must panic, not misread
    let wrong = VecSet::zeros(5, 7);
    assert!(std::panic::catch_unwind(|| model.predict(&wrong)).is_err());
}
