//! Integration tests for the hierarchical routing tree: exactness of the
//! beam ≥ k contract through the public `FittedModel` surface, assignment
//! agreement at the default beam on clustered data, the `route_min_k`
//! dispatch gate, and the routed artifact round trip (save → load →
//! predict/search from the loaded model).

use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::gkm::ann::SearchParams;
use gkmeans::gkm::tree::{RouteTreeParams, ROUTE_MIN_K};
use gkmeans::model::{Clusterer, FittedModel, GkMeans, RunContext};
use gkmeans::runtime::Backend;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gkm_route_{}_{name}", std::process::id()))
}

/// A fitted GK-means model with retained data and an attached routing
/// tree.  `branch` is kept small so the tree is genuinely multi-level
/// even at test-scale k.
fn routed_fit(n: usize, d: usize, k: usize, branch: usize, seed: u64) -> FittedModel {
    let data = blobs(&BlobSpec::quick(n, d, k), seed);
    let backend = Backend::native();
    let ctx = RunContext::new(&backend).max_iters(5).keep_data(true);
    let mut model = GkMeans::new(k).kappa(8).tau(3).fit(&data, &ctx);
    model.build_route(&RouteTreeParams { branch, ..Default::default() });
    let tree = model.route.as_ref().expect("build_route attaches a tree");
    assert!(tree.depth() > 1, "branch={branch} k={k} must yield a multi-level tree");
    assert!(tree.has_reps(), "labels cover the training set, so reps attach");
    model
}

#[test]
fn routed_predict_with_beam_geq_k_is_bit_identical_to_flat() {
    let k = 48;
    let mut model = routed_fit(1500, 12, k, 4, 42);
    let queries = blobs(&BlobSpec::quick(400, 12, k), 43);

    let tree = model.route.clone();
    model.route = None;
    let flat = model.predict(&queries);

    model.route = tree;
    model.route_min_k = 0; // engage routing below the default k threshold
    model.route.as_mut().unwrap().default_beam = k as u32; // beam ≥ k ⇒ exact
    let routed = model.predict(&queries);

    assert_eq!(routed, flat, "beam ≥ k must reproduce the flat scan bit-for-bit");
    // … and through the streaming entry point too
    assert_eq!(model.predict_batch(&queries), flat);
}

#[test]
fn default_beam_keeps_assignment_agreement_high_on_clustered_data() {
    let k = 64;
    let mut model = routed_fit(2000, 16, k, 4, 7);
    let queries = blobs(&BlobSpec::quick(600, 16, k), 8);

    let tree = model.route.clone();
    model.route = None;
    let flat = model.predict(&queries);

    model.route = tree;
    model.route_min_k = 0;
    let routed = model.predict(&queries);

    let agree = flat.iter().zip(&routed).filter(|(a, b)| a == b).count() as f64
        / flat.len() as f64;
    assert!(
        agree >= 0.95,
        "default-beam routed assignment agreement {agree:.4} < 0.95"
    );
}

#[test]
fn route_min_k_gates_routed_dispatch() {
    let model = routed_fit(1200, 12, 32, 4, 11);
    // test-scale k is far below the engagement threshold: the tree is
    // attached but dormant, and predict is the flat scan
    assert!(model.route.is_some());
    assert_eq!(model.route_min_k, ROUTE_MIN_K);
    assert!(!model.routing_active(), "k=32 < ROUTE_MIN_K must stay flat");

    let mut forced = model.clone();
    forced.route_min_k = 0;
    assert!(forced.routing_active());

    let mut off = model.clone();
    off.route = None;
    off.route_min_k = 0;
    assert!(!off.routing_active(), "no tree ⇒ never active");
}

#[test]
fn routed_artifact_roundtrip_predicts_and_searches() {
    let k = 48;
    let mut model = routed_fit(1500, 12, k, 4, 99);
    model.route_min_k = 0;

    let path = tmp("routed_roundtrip.gkm");
    model.save(&path).unwrap();
    let mut loaded = FittedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.route, model.route, "routing tree must round-trip exactly");
    // route_min_k is an in-memory dispatch knob, not part of the artifact
    loaded.route_min_k = 0;

    let queries = blobs(&BlobSpec::quick(300, 12, k), 100);
    assert_eq!(
        loaded.predict(&queries),
        model.predict(&queries),
        "routed predict must be bit-identical across the round trip"
    );

    // routed graph-ANN search from the loaded artifact: seeded entries
    // come from the tree's per-leaf representatives
    assert!(loaded.routing_active() && loaded.route.as_ref().unwrap().has_reps());
    let sp = SearchParams { ef: 64, entries: 8, seed: 5 };
    let q = queries.row(17);
    let hits = loaded.search(q, 10, &sp).expect("routed search serves");
    assert_eq!(hits.len(), 10);
    assert_eq!(
        hits,
        model.search(q, 10, &sp).unwrap(),
        "routed search must be deterministic across the round trip"
    );
}
