//! PJRT ⇄ native cross-checks: the AOT-compiled Pallas artifacts must
//! compute the same numbers as the native mirror.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a note) when `artifacts/manifest.tsv` is absent so `cargo test`
//! works on a fresh checkout.

use gkmeans::data::matrix::VecSet;
use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::runtime::{artifact, Backend};
use gkmeans::util::rng::Rng;

fn pjrt_backend() -> Option<Backend> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without the `pjrt` feature (offline default)");
        return None;
    }
    let dir = artifact::default_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Backend::pjrt(&dir).expect("pjrt backend"))
}

fn rand_flat(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

#[test]
fn block_l2_matches_native_all_dims() {
    let Some(pjrt) = pjrt_backend() else { return };
    let native = Backend::native();
    let mut rng = Rng::new(1);
    for &d in &[32usize, 100, 128, 512, 960] {
        // sizes chosen to exercise exact-fit, tail-padding and multi-block
        for &(m, n) in &[(256usize, 256usize), (300, 70), (64, 512), (13, 5)] {
            let x = rand_flat(&mut rng, m * d, 1.0);
            let y = rand_flat(&mut rng, n * d, 1.0);
            let mut a = vec![0f32; m * n];
            let mut b = vec![0f32; m * n];
            native.block_l2(&x, &y, d, &mut a);
            pjrt.block_l2(&x, &y, d, &mut b);
            for i in 0..m * n {
                assert!(
                    (a[i] - b[i]).abs() <= 1e-2 + 1e-4 * a[i].abs(),
                    "d={d} m={m} n={n} idx={i}: native={} pjrt={}",
                    a[i],
                    b[i]
                );
            }
        }
    }
}

#[test]
fn assign_matches_native() {
    let Some(pjrt) = pjrt_backend() else { return };
    let native = Backend::native();
    let mut rng = Rng::new(2);
    for &d in &[32usize, 128] {
        let (m, k) = (500, 300); // forces row + column padding
        let x = rand_flat(&mut rng, m * d, 1.0);
        let c = rand_flat(&mut rng, k * d, 1.0);
        let a = native.assign_blocks(&x, &c, d, k);
        let b = pjrt.assign_blocks(&x, &c, d, k);
        let mut disagreements = 0;
        for i in 0..m {
            assert!(
                (a.best[i] - b.best[i]).abs() <= 1e-2 + 1e-4 * a.best[i].abs(),
                "d={d} row={i}: {} vs {}",
                a.best[i],
                b.best[i]
            );
            if a.idx[i] != b.idx[i] {
                disagreements += 1; // only legitimate on fp near-ties
                let da = a.best[i];
                let db = b.best[i];
                assert!((da - db).abs() <= 1e-2, "non-tie index disagreement at {i}");
            }
        }
        assert!(disagreements <= m / 50, "too many index disagreements: {disagreements}");
    }
}

#[test]
fn bisect_margins_match_native() {
    let Some(pjrt) = pjrt_backend() else { return };
    let native = Backend::native();
    let data = blobs(&BlobSpec::quick(700, 32, 4), 3);
    let subset: Vec<u32> = (0..700).step_by(2).map(|i| i as u32).collect();
    let mut rng = Rng::new(4);
    let c0 = rand_flat(&mut rng, 32, 1.0);
    let c1 = rand_flat(&mut rng, 32, 1.0);
    let mut a = vec![0f32; subset.len()];
    let mut b = vec![0f32; subset.len()];
    native.bisect_margins(&data, &subset, &c0, &c1, &mut a);
    pjrt.bisect_margins(&data, &subset, &c0, &c1, &mut b);
    for i in 0..subset.len() {
        assert!(
            (a[i] - b[i]).abs() <= 2e-2 + 1e-3 * a[i].abs(),
            "t={i}: native={} pjrt={}",
            a[i],
            b[i]
        );
    }
}

#[test]
fn pairwise_among_matches_native() {
    let Some(pjrt) = pjrt_backend() else { return };
    let native = Backend::native();
    let data = blobs(&BlobSpec::quick(200, 32, 4), 5);
    let rows: Vec<u32> = (0..50u32).collect(); // typical ξ-sized cell
    let mut a = vec![0f32; 50 * 50];
    let mut b = vec![0f32; 50 * 50];
    native.pairwise_among(&data, &rows, &mut a);
    pjrt.pairwise_among_pjrt(&data, &rows, &mut b);
    for i in 0..a.len() {
        assert!((a[i] - b[i]).abs() <= 1e-2 + 1e-4 * a[i].abs(), "idx={i}");
    }
}

#[test]
fn unsupported_dim_falls_back_to_native() {
    let Some(pjrt) = pjrt_backend() else { return };
    // d=7 has no artifact; the call must still return correct numbers
    let mut rng = Rng::new(6);
    let x = rand_flat(&mut rng, 10 * 7, 1.0);
    let y = rand_flat(&mut rng, 4 * 7, 1.0);
    let mut got = vec![0f32; 40];
    pjrt.block_l2(&x, &y, 7, &mut got);
    let mut want = vec![0f32; 40];
    Backend::native().block_l2(&x, &y, 7, &mut want);
    assert_eq!(got, want);
}

#[test]
fn full_clustering_agrees_across_backends() {
    let Some(pjrt) = pjrt_backend() else { return };
    // same job, both backends: distortion must agree closely (identical
    // algorithm, fp-level differences only).
    let data = blobs(&BlobSpec::quick(1500, 32, 12), 7);
    let params = gkmeans::kmeans::common::KmeansParams { max_iters: 8, ..Default::default() };
    let a = gkmeans::kmeans::lloyd::run_core(&data, 12, &params, &Backend::native());
    let b = gkmeans::kmeans::lloyd::run_core(&data, 12, &params, &pjrt);
    let (da, db) = (a.distortion(), b.distortion());
    assert!(
        (da - db).abs() <= 0.05 * da.max(db),
        "native={da} pjrt={db}"
    );
}

#[test]
fn vecset_dims_cover_paper_datasets() {
    // guard: the artifact set must cover every synthetic dataset's dim
    let Some(_) = pjrt_backend() else { return };
    let m = artifact::Manifest::load(&artifact::default_dir()).unwrap();
    for d in [100, 128, 512, 960] {
        assert!(m.get("block_l2", d).is_some(), "missing block_l2 d={d}");
        assert!(m.get("assign_argmin", d).is_some(), "missing assign d={d}");
    }
    let _ = VecSet::zeros(1, 1); // silence unused import lint paranoia
}
