//! End-to-end tests for the `gkm-serve` subsystem (PR 7): the sharded
//! scatter-gather equivalence, the micro-batcher's coalesced ≡
//! sequential guarantee over the wire, protocol hardening against
//! garbage bytes, and disk-backed serving with live cache stats.

use std::time::Duration;

use gkmeans::coordinator::job::Method;
use gkmeans::data::matrix::VecSet;
use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::gkm::ann::SearchParams;
use gkmeans::graph::brute;
use gkmeans::model::{Clusterer, FittedModel, GkMeans, ModelVectors, RunContext};
use gkmeans::runtime::Backend;
use gkmeans::serve::proto::{self, stats_value, Client, Request, Response};
use gkmeans::serve::{ServeConfig, Server, ShardedIndex};

/// A minimal servable model over `data` whose KNN graph is *complete*
/// (κ = n−1): greedy graph search expands every node from the first
/// frontier pop, so `search` is exact for any `ef ≥ topk` — which is
/// what lets the sharded-vs-union test demand bitwise equality rather
/// than recall overlap.
fn exact_model(data: &VecSet) -> FittedModel {
    let n = data.rows();
    let backend = Backend::native();
    let graph = brute::build(data, n - 1, &backend);
    FittedModel {
        method: Method::GkMeans,
        k: 1,
        dim: data.dim(),
        n_train: n,
        threads: 1,
        centroids: VecSet::zeros(1, data.dim()),
        labels: vec![0; n],
        history: Vec::new(),
        total_seconds: 0.0,
        init_seconds: 0.0,
        graph_seconds: 0.0,
        graph: Some(graph),
        data: Some(ModelVectors::Ram(data.clone())),
        quantized: None,
        route: None,
        route_min_k: gkmeans::gkm::tree::ROUTE_MIN_K,
    }
}

/// Split `data`'s rows into `parts` contiguous slices.
fn split_rows(data: &VecSet, parts: usize) -> Vec<VecSet> {
    let n = data.rows();
    let d = data.dim();
    let chunk = (n + parts - 1) / parts;
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let mut flat = Vec::with_capacity((hi - lo) * d);
        for i in lo..hi {
            flat.extend_from_slice(data.row(i));
        }
        out.push(VecSet::from_flat(d, flat));
        lo = hi;
    }
    out
}

#[test]
fn sharded_search_equals_union_search() {
    // 240 rows so 2/3/4 shards all split evenly-ish; complete graphs
    // make every per-shard search exact, so the scatter-gather merge
    // must reproduce the union model's top-k *exactly* — ids, distances
    // and (dist, id) tie-break order included.
    let data = blobs(&BlobSpec::quick(240, 8, 5), 17);
    let union_model = exact_model(&data);
    let queries: Vec<Vec<f32>> = (0..12)
        .map(|i| data.row(i * 17 % data.rows()).to_vec())
        .collect();
    for shards in [1usize, 2, 3, 4] {
        let parts = split_rows(&data, shards);
        let index =
            ShardedIndex::new(parts.iter().map(exact_model).collect()).expect("index");
        assert_eq!(index.total_rows(), data.rows());
        for ef in [8usize, 32, 64] {
            for topk in [1usize, 5, 8] {
                let params = SearchParams { ef: ef.max(topk), ..SearchParams::default() };
                for q in &queries {
                    let want = union_model.search(q, topk, &params).unwrap();
                    let got = index.search(q, topk, &params).unwrap();
                    assert_eq!(
                        got, want,
                        "shards={shards} ef={ef} topk={topk}: sharded result diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn sharded_artifacts_from_disk_equal_union() {
    // the production path: each shard saved as a GKMODEL artifact and
    // re-loaded (vectors paged from disk), then merged — must still
    // equal the in-RAM union search, and the chunk cache must record
    // traffic
    let data = blobs(&BlobSpec::quick(160, 6, 4), 23);
    let union_model = exact_model(&data);
    let dir = std::env::temp_dir().join(format!("gkm_serve_shards_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut loaded = Vec::new();
    for (s, part) in split_rows(&data, 2).iter().enumerate() {
        let path = dir.join(format!("shard{s}.gkm"));
        exact_model(part).save(&path).expect("save shard");
        let m = FittedModel::load(&path).expect("load shard");
        assert!(
            matches!(m.data, Some(ModelVectors::Disk(_))),
            "v2 artifact must page vectors from disk"
        );
        loaded.push(m);
    }
    let index = ShardedIndex::new(loaded).expect("index");
    assert!(index.any_disk_backed());
    let params = SearchParams::default();
    for i in 0..10 {
        let q = data.row(i * 13 % data.rows());
        let want = union_model.search(q, 6, &params).unwrap();
        let got = index.search(q, 6, &params).unwrap();
        assert_eq!(got, want, "query {i}: disk-backed sharded result diverged");
    }
    let (hits, misses) = index.cache_totals().expect("disk shards expose cache stats");
    assert!(hits + misses > 0, "searches must touch the chunk cache");
    std::fs::remove_dir_all(&dir).ok();
}

fn fitted_serving_model() -> (FittedModel, VecSet) {
    let data = blobs(&BlobSpec::quick(300, 6, 4), 31);
    let b = Backend::native();
    let ctx = RunContext::new(&b).max_iters(3).keep_data(true);
    let model = GkMeans::new(4).kappa(8).tau(2).xi(30).fit(&data, &ctx);
    (model, data)
}

#[test]
fn coalesced_batches_equal_sequential_singles() {
    // the micro-batcher contract, end to end over TCP: N concurrent
    // clients inside one wide window get *bitwise* the answers a lone
    // sequential client gets, at any window / max_batch setting
    let (model, data) = fitted_serving_model();
    let engine_params = SearchParams::default();
    let queries: Vec<Vec<f32>> = (0..24).map(|i| data.row(i * 7).to_vec()).collect();
    let expected: Vec<Vec<(u32, f32)>> = queries
        .iter()
        .map(|q| {
            model
                .search(q, 5, &engine_params)
                .unwrap()
                .into_iter()
                .map(|(d, id)| (id, d))
                .collect()
        })
        .collect();
    for (window_us, max_batch) in [(0u64, 1usize), (2000, 8), (5000, 64)] {
        let index = ShardedIndex::new(vec![model.clone()]).unwrap();
        let cfg = ServeConfig {
            batch_window: Duration::from_micros(window_us),
            max_batch,
            ..ServeConfig::default()
        };
        let handle = Server::start(index, &cfg).expect("start");
        let addr = handle.addr();
        // concurrent: one client thread per query, all in flight together
        let got: Vec<Vec<(u32, f32)>> = std::thread::scope(|s| {
            let handles: Vec<_> = queries
                .iter()
                .map(|q| {
                    s.spawn(move || {
                        let mut c = Client::connect(addr).expect("connect");
                        c.search(q, 5, 0).expect("search")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            got, expected,
            "window={window_us}us max_batch={max_batch}: coalesced != sequential"
        );
        // batching actually happened where it was allowed to
        let stats = Client::connect(addr).unwrap().stats().unwrap();
        let batches = stats_value(&stats, "batches").unwrap();
        assert!(batches >= 1.0, "{stats}");
        if max_batch == 1 {
            assert_eq!(stats_value(&stats, "batch_max"), Some(1.0), "{stats}");
        }
        handle.shutdown();
    }
}

#[test]
fn disk_backed_server_reports_cache_stats_and_percentiles() {
    let (model, data) = fitted_serving_model();
    let path = std::env::temp_dir().join(format!("gkm_serve_disk_{}.gkm", std::process::id()));
    model.save(&path).expect("save");
    let served = FittedModel::load(&path).expect("load");
    assert!(served.cache_stats().is_some());
    let index = ShardedIndex::new(vec![served]).unwrap();
    let cfg = ServeConfig { max_batch: 8, ..ServeConfig::default() };
    let handle = Server::start(index, &cfg).expect("start");
    let mut c = Client::connect(handle.addr()).unwrap();
    for i in 0..30 {
        c.search(data.row(i * 3), 5, 0).expect("search");
    }
    let stats = c.stats().unwrap();
    assert!(stats_value(&stats, "lat_p50_us").unwrap() > 0.0, "{stats}");
    assert!(stats_value(&stats, "lat_p99_us").unwrap() > 0.0, "{stats}");
    assert_eq!(stats_value(&stats, "searches"), Some(30.0), "{stats}");
    let rate = stats_value(&stats, "cache_hit_rate").expect("disk config exposes cache rate");
    assert!(
        rate > 0.0 && rate <= 1.0,
        "repeated searches over one chunked file must hit the cache: {stats}"
    );
    handle.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn garbage_and_disconnects_leave_the_server_healthy() {
    use std::io::Write as _;
    let (model, data) = fitted_serving_model();
    let index = ShardedIndex::new(vec![model]).unwrap();
    let cfg = ServeConfig { max_batch: 8, ..ServeConfig::default() };
    let handle = Server::start(index, &cfg).expect("start");
    let addr = handle.addr();
    // a long-lived healthy client that must survive everything below
    let mut healthy = Client::connect(addr).unwrap();
    healthy.ping().unwrap();

    // 1. pseudorandom garbage streams (no valid framing at all)
    let mut seed = 0x9E37_79B9u32;
    for round in 0..5 {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let mut junk = Vec::with_capacity(64);
        for _ in 0..64 {
            seed = seed.wrapping_mul(1664525).wrapping_add(1013904223 + round);
            junk.push((seed >> 24) as u8);
        }
        s.write_all(&junk).ok();
        drop(s); // disconnect without reading the (possible) error reply
    }
    // 2. a well-framed junk payload, then a valid request on the same
    //    connection — the typed error must not poison the stream
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    proto::write_frame(&mut s, &[0xAB, 0xCD, 0xEF]).unwrap();
    let r = proto::read_frame(&mut s).unwrap().unwrap();
    assert!(matches!(proto::decode_response(&r).unwrap(), Response::Error(_)));
    proto::write_frame(&mut s, &proto::encode_request(&Request::Ping)).unwrap();
    let r = proto::read_frame(&mut s).unwrap().unwrap();
    assert!(matches!(proto::decode_response(&r).unwrap(), Response::Pong));
    // 3. a client that sends a length prefix and dies mid-payload
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(&64u32.to_le_bytes()).unwrap();
    s.write_all(&[1u8, 2, 3]).unwrap();
    drop(s);
    // 4. an oversized frame announcement
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(&(u32::MAX).to_le_bytes()).unwrap();
    let r = proto::read_frame(&mut s).unwrap().unwrap();
    match proto::decode_response(&r).unwrap() {
        Response::Error(e) => assert!(e.contains("cap"), "{e}"),
        other => panic!("expected typed error, got {other:?}"),
    }

    // the original connection still serves real queries afterwards
    std::thread::sleep(Duration::from_millis(100));
    let hits = healthy.search(data.row(0), 5, 0).expect("healthy client survives");
    assert!(!hits.is_empty());
    let stats = healthy.stats().unwrap();
    assert!(
        stats_value(&stats, "degraded").unwrap() >= 1.0,
        "protocol abuse must be counted: {stats}"
    );
    handle.shutdown();
}

#[test]
fn hostile_topk_and_ef_cannot_size_allocations() {
    // the OOM regression: a single small SEARCH frame carrying
    // topk=u32::MAX used to reach Vec::with_capacity(topk * shards) and
    // TopK::new(ef) and abort the process on allocation failure.  Now
    // the decode layer rejects anything past MAX_TOPK/MAX_EF with a
    // typed error, and in-range values are clamped to the row count.
    let (model, data) = fitted_serving_model();
    let rows = data.rows();
    let index = ShardedIndex::new(vec![model]).unwrap();
    let handle = Server::start(index, &ServeConfig::default()).expect("start");
    let addr = handle.addr();

    let raw_search = |topk: u32, ef: u32| {
        let mut payload = vec![2u8]; // VERB_SEARCH
        payload.extend(topk.to_le_bytes());
        payload.extend(ef.to_le_bytes());
        payload.extend((data.dim() as u32).to_le_bytes());
        for &v in data.row(0) {
            payload.extend(v.to_le_bytes());
        }
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        proto::write_frame(&mut s, &payload).unwrap();
        let r = proto::read_frame(&mut s).unwrap().unwrap();
        proto::decode_response(&r).unwrap()
    };

    match raw_search(u32::MAX, 0) {
        Response::Error(e) => assert!(e.contains("topk"), "{e}"),
        other => panic!("hostile topk must be a typed error, got {other:?}"),
    }
    match raw_search(1, u32::MAX) {
        Response::Error(e) => assert!(e.contains("ef"), "{e}"),
        other => panic!("hostile ef must be a typed error, got {other:?}"),
    }
    // in-range but larger than the dataset: clamped to the row count,
    // served normally (never more hits than rows exist)
    match raw_search(proto::MAX_TOPK, proto::MAX_EF) {
        Response::Hits(hits) => {
            assert!(!hits.is_empty() && hits.len() <= rows, "{} hits", hits.len());
        }
        other => panic!("clamped search must succeed, got {other:?}"),
    }
    // the server is still healthy after all of the above
    let mut c = Client::connect(addr).unwrap();
    c.ping().unwrap();
    assert!(!c.search(data.row(1), 5, 0).unwrap().is_empty());
    handle.shutdown();
}

#[test]
fn degraded_batch_reports_per_query_errors_not_poison() {
    // a predict whose dim matches but whose batch neighbor is fine:
    // send a search and a predict through one server; then check a
    // wrong-dim request produces a typed error while the connection and
    // subsequent requests keep working (the satellite-6 regression)
    let (model, data) = fitted_serving_model();
    let index = ShardedIndex::new(vec![model.clone()]).unwrap();
    let handle = Server::start(index, &ServeConfig::default()).expect("start");
    let mut c = Client::connect(handle.addr()).unwrap();
    let err = c.search(&[1.0, 2.0, 3.0], 4, 0).unwrap_err();
    assert!(err.contains("dim"), "{err}");
    let label = c.predict(data.row(0)).expect("predict after error");
    assert_eq!(label, model.predict_batch(&data)[0]);
    handle.shutdown();
}
