//! Deterministic tests of the paper's *structural* claims — the ones that
//! don't need wall-clock (which is noisy on a shared box):
//!
//! 1. §4.2: the candidate set Q a sample visits has |Q| ≤ κ, and after
//!    dedup is typically much smaller ("the number of clusters one sample
//!    visits is even smaller than κ").
//! 2. §4.5: |Q| is independent of k — the whole point of the algorithm.
//! 3. §1/Fig. 1: neighbors co-occur in clusters far above chance, which
//!    is what makes 1–2 work.

use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::graph::knn::KnnGraph;
use gkmeans::kmeans::common::Clustering;
use gkmeans::kmeans::two_means::{self, TwoMeansParams};
use gkmeans::runtime::Backend;

/// Average distinct candidate-cluster count per sample for a partition.
fn mean_candidates(graph: &KnnGraph, c: &Clustering, kappa: usize) -> f64 {
    let n = graph.n();
    let mut total = 0usize;
    let mut q: Vec<u32> = Vec::with_capacity(kappa);
    for i in 0..n {
        q.clear();
        for &b in graph.neighbors(i).iter().take(kappa) {
            if b != u32::MAX {
                let lbl = c.labels[b as usize];
                if !q.contains(&lbl) {
                    q.push(lbl);
                }
            }
        }
        total += q.len();
    }
    total as f64 / n as f64
}

fn setup(n: usize) -> (gkmeans::data::matrix::VecSet, KnnGraph) {
    let data = blobs(&BlobSpec::quick(n, 16, 20), 5);
    let graph = construct::build(
        &data,
        &ConstructParams { kappa: 20, xi: 40, tau: 5, seed: 2, threads: 1, ..Default::default() },
        &Backend::native(),
    )
    .graph;
    (data, graph)
}

#[test]
fn candidate_sets_are_small_and_bounded() {
    let (data, graph) = setup(3000);
    let kappa = 20;
    let labels = two_means::run(&data, 60, &TwoMeansParams::default(), &Backend::native());
    let c = Clustering::from_labels(&data, labels, 60);
    let mean_q = mean_candidates(&graph, &c, kappa);
    assert!(mean_q <= kappa as f64, "|Q| must be ≤ κ");
    // §4.2: dedup makes it *much* smaller than κ on clustered data
    assert!(
        mean_q < kappa as f64 * 0.6,
        "mean |Q| = {mean_q} not ≪ κ = {kappa}"
    );
}

#[test]
fn candidate_count_is_independent_of_k() {
    // The paper's complexity claim: per-sample work is O(κ·d) regardless
    // of k.  Measure mean |Q| at three very different k and require the
    // variation to be modest (it can grow a little: more clusters = more
    // distinct labels among fixed neighbors — bounded by κ always).
    let (data, graph) = setup(3000);
    let kappa = 20;
    let mut means = Vec::new();
    for k in [30usize, 150, 750] {
        let labels = two_means::run(&data, k, &TwoMeansParams::default(), &Backend::native());
        let c = Clustering::from_labels(&data, labels, k);
        means.push(mean_candidates(&graph, &c, kappa));
    }
    // 25x more clusters must NOT mean 25x more work: growth must be
    // strongly sub-linear in k and always capped by kappa.  (Measured
    // here: ~4.8x for a 25x k increase, i.e. |Q| tracks the neighborhood
    // label diversity, not k.)
    assert!(
        means[2] <= kappa as f64,
        "|Q| exceeded kappa: {means:?}"
    );
    assert!(
        means[2] < means[0] * 25.0 * 0.35,
        "candidate growth with k too steep (super-sublinear bound): {means:?}"
    );
    println!("mean |Q| at k=30/150/750: {means:?}");
}

#[test]
fn per_epoch_move_cost_tracks_candidates_not_k() {
    // End-to-end corollary: GK-means' iteration phase does ~n·mean|Q|
    // candidate evaluations.  We assert the *distortion trajectory*
    // still converges properly at large k (i.e. the pruning is not
    // destroying the optimization) — the timing half of this claim is
    // covered by fig6_scalability.
    let (data, graph) = setup(3000);
    let params = gkmeans::gkm::gkmeans::GkMeansParams {
        kappa: 20,
        base: gkmeans::kmeans::common::KmeansParams { max_iters: 12, ..Default::default() },
    };
    for k in [30usize, 300] {
        let out = gkmeans::gkm::gkmeans::run_core(&data, k, &graph, &params, &Backend::native());
        let first = out.history.first().unwrap().distortion;
        let last = out.history.last().unwrap().distortion;
        assert!(last <= first, "k={k}: no improvement");
        out.clustering.check_invariants(&data).unwrap();
    }
}

#[test]
fn cooccurrence_premise_holds_on_every_standin() {
    // Fig. 1's premise is what justifies the candidate pruning; verify it
    // on all four dataset geometries (weakest on glove-like, per paper).
    for kind in ["sift", "vlad", "glove", "gist"] {
        let n = 800;
        let data = gkmeans::data::synth::by_name(kind, n, 3).unwrap();
        let k = n / 50;
        let labels = two_means::run(&data, k, &TwoMeansParams::default(), &Backend::native());
        let exact = gkmeans::graph::brute::build(&data, 1, &Backend::native());
        let series = gkmeans::eval::cooccur::cooccurrence_by_rank(&exact, &labels, 1);
        let random = gkmeans::eval::cooccur::random_collision_rate(&labels, k);
        assert!(
            series[0] > 3.0 * random,
            "{kind}: NN co-occurrence {} not ≫ random {random}",
            series[0]
        );
    }
}
