//! Property-based tests over coordinator/clustering/graph invariants,
//! using the in-tree `testing::prop` framework (routing, batching and
//! state invariants the whole system relies on).

use gkmeans::gkm::construct;
use gkmeans::gkm::gkmeans as gk;
use gkmeans::graph::knn::KnnGraph;
use gkmeans::kmeans::common::{Clustering, KmeansParams};
use gkmeans::kmeans::two_means::{self, TwoMeansParams};
use gkmeans::runtime::Backend;
use gkmeans::testing::prop;

#[test]
fn prop_two_means_partition_is_balanced_and_total() {
    prop::check("2M-tree partition", 12, |g| {
        let n = g.usize_in(20, 400);
        let d = g.usize_in(2, 24);
        let k = g.usize_in(2, n.min(32));
        let data = g.matrix(n, d, 5.0);
        let labels = two_means::run(&data, k, &TwoMeansParams::default(), &Backend::native());
        if labels.len() != n {
            return Err("label count".into());
        }
        let mut counts = vec![0usize; k];
        for &l in &labels {
            if l as usize >= k {
                return Err(format!("label {l} >= k {k}"));
            }
            counts[l as usize] += 1;
        }
        if counts.iter().any(|&c| c == 0) {
            return Err(format!("empty cluster: {counts:?}"));
        }
        let (mx, mn) = (*counts.iter().max().unwrap(), *counts.iter().min().unwrap());
        if mx > 2 * mn + 2 {
            return Err(format!("unbalanced: {counts:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_moves_never_increase_distortion() {
    prop::check("BKM/GK moves monotone", 10, |g| {
        let n = g.usize_in(50, 300);
        let d = g.usize_in(2, 16);
        let k = g.usize_in(2, 12);
        let data = g.matrix(n, d, 3.0);
        let kappa = g.usize_in(1, 8);
        let graph = gkmeans::graph::brute::build(&data, kappa, &Backend::native());
        let params = gk::GkMeansParams {
            kappa,
            base: KmeansParams { max_iters: 6, seed: g.rng.next_u64(), ..Default::default() },
        };
        let out = gk::run_core(&data, k, &graph, &params, &Backend::native());
        for w in out.history.windows(2) {
            if w[1].distortion > w[0].distortion + 1e-6 * (1.0 + w[0].distortion) {
                return Err(format!("distortion rose {} -> {}", w[0].distortion, w[1].distortion));
            }
        }
        out.clustering.check_invariants(&data).map_err(|e| e)?;
        Ok(())
    });
}

#[test]
fn prop_graph_updates_preserve_invariants() {
    prop::check("graph update stress", 20, |g| {
        let n = g.usize_in(4, 100);
        let kappa = g.usize_in(1, 12);
        let mut graph = KnnGraph::empty(n, kappa);
        for _ in 0..g.usize_in(10, 800) {
            let i = g.usize_in(0, n - 1);
            let mut j = g.usize_in(0, n - 1);
            if j == i {
                j = (j + 1) % n;
            }
            graph.update(i, j as u32, g.f32_in(0.0, 100.0));
        }
        graph.check_invariants()
    });
}

#[test]
fn prop_construct_graph_entries_are_true_distances() {
    prop::check("alg3 distances exact", 6, |g| {
        let n = g.usize_in(60, 250);
        let d = g.usize_in(2, 12);
        let data = g.matrix(n, d, 4.0);
        let params = construct::ConstructParams {
            kappa: g.usize_in(2, 6),
            xi: g.usize_in(10, 40),
            tau: g.usize_in(1, 4),
            seed: g.rng.next_u64(),
            threads: 1,
            ..Default::default()
        };
        let out = construct::build(&data, &params, &Backend::native());
        out.graph.check_invariants()?;
        for i in (0..n).step_by(7) {
            for (t, &j) in out.graph.neighbors(i).iter().enumerate() {
                if j == u32::MAX {
                    continue;
                }
                let want = gkmeans::core_ops::dist::d2(data.row(i), data.row(j as usize));
                let got = out.graph.distances(i)[t];
                if (got - want).abs() > 1e-2 * (1.0 + want) {
                    return Err(format!("({i},{j}): {got} vs {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_composite_vectors_track_labels() {
    prop::check("composite bookkeeping", 15, |g| {
        let n = g.usize_in(10, 150);
        let d = g.usize_in(1, 10);
        let k = g.usize_in(1, 8);
        let data = g.matrix(n, d, 2.0);
        let labels: Vec<u32> = (0..n).map(|_| g.usize_in(0, k - 1) as u32).collect();
        let mut c = Clustering::from_labels(&data, labels, k);
        // random legal moves
        for _ in 0..g.usize_in(0, 60) {
            let i = g.usize_in(0, n - 1);
            let u = c.labels[i] as usize;
            let v = g.usize_in(0, k - 1);
            if u != v && c.counts[u] > 1 {
                c.apply_move(i, data.row(i), u, v);
            }
        }
        c.check_invariants(&data)
    });
}

#[test]
fn prop_d2_batch_matches_scalar_l2() {
    // the batched candidate kernel (both the norm-identity form and its
    // scalar fallback) must agree with per-candidate scalar l2 over
    // random dims and candidate widths; the exact-form sibling must
    // agree to the bit
    use gkmeans::core_ops::dist::{d2, d2_batch, d2_batch_exact, norm2};
    prop::check("batched candidate eval ≡ scalar", 25, |g| {
        let d = g.usize_in(1, 200);
        let w = g.usize_in(1, 24);
        let x = g.normal_vec(d);
        let block = g.normal_vec(w * d);
        let xx = norm2(&x);
        let norms: Vec<f32> = block.chunks_exact(d).map(norm2).collect();
        let mut out = vec![0f32; w];
        d2_batch(&x, xx, &block, &norms, d, &mut out);
        let mut exact = vec![0f32; w];
        d2_batch_exact(&x, &block, d, &mut exact);
        for j in 0..w {
            let want = d2(&x, &block[j * d..(j + 1) * d]);
            if (out[j] - want).abs() > 1e-3 * (1.0 + want) {
                return Err(format!("d={d} w={w} col {j}: {} vs {want}", out[j]));
            }
            if exact[j].to_bits() != want.to_bits() {
                return Err(format!("exact kernel shifted a bit at d={d} w={w} col {j}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_assign_blocks_matches_scalar() {
    prop::check("assign routing", 10, |g| {
        let d = g.usize_in(1, 40);
        let m = g.usize_in(1, 300);
        let k = g.usize_in(1, 300);
        let x = g.normal_vec(m * d);
        let c = g.normal_vec(k * d);
        let acc = Backend::native().assign_blocks(&x, &c, d, k);
        for i in (0..m).step_by(11.max(m / 7)) {
            let xi = &x[i * d..(i + 1) * d];
            let mut best = f32::INFINITY;
            let mut bidx = 0u32;
            for j in 0..k {
                let dd = gkmeans::core_ops::dist::d2(xi, &c[j * d..(j + 1) * d]);
                if dd < best {
                    best = dd;
                    bidx = j as u32;
                }
            }
            if acc.idx[i] != bidx && (acc.best[i] - best).abs() > 1e-3 * (1.0 + best) {
                return Err(format!("row {i}: idx {} vs {bidx}", acc.idx[i]));
            }
        }
        Ok(())
    });
}
