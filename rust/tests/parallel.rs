//! Cross-module tests for the parallel execution layer (`util::pool` and
//! its consumers): equivalence with the serial paths, determinism per
//! `(seed, threads)`, and the monotone-distortion invariant under the
//! batch-synchronous GK-means commit protocol.

use gkmeans::gkm::gkmeans as gk;
use gkmeans::graph::{brute, nn_descent, recall};
use gkmeans::kmeans::common::KmeansParams;
use gkmeans::kmeans::two_means::{self, TwoMeansParams};
use gkmeans::runtime::Backend;
use gkmeans::testing::prop;
use gkmeans::util::pool;

#[test]
fn prop_parallel_gkmeans_valid_monotone_and_near_serial() {
    // The satellite acceptance property: threads = N produces a valid
    // clustering with distortion within tolerance of threads = 1, and the
    // distortion history stays monotone non-increasing.
    prop::check("parallel GK-means ≈ serial", 8, |g| {
        let n = g.usize_in(200, 700);
        let d = g.usize_in(4, 16);
        let k = g.usize_in(4, 16);
        let kappa = g.usize_in(2, 10);
        let threads = g.usize_in(2, 4);
        let data = g.matrix(n, d, 4.0);
        let graph = brute::build(&data, kappa, &Backend::native());
        let seed = g.rng.next_u64();
        let base = KmeansParams { max_iters: 10, seed, ..Default::default() };
        let serial = gk::run_core(
            &data,
            k,
            &graph,
            &gk::GkMeansParams { kappa, base: base.clone() },
            &Backend::native(),
        );
        let par = gk::run_core(
            &data,
            k,
            &graph,
            &gk::GkMeansParams { kappa, base: KmeansParams { threads, ..base } },
            &Backend::native(),
        );
        par.clustering.check_invariants(&data)?;
        for w in par.history.windows(2) {
            if w[1].distortion > w[0].distortion + 1e-6 * (1.0 + w[0].distortion) {
                return Err(format!(
                    "distortion rose under threads={threads}: {} -> {}",
                    w[0].distortion, w[1].distortion
                ));
            }
        }
        // different 2M-tree split trees → different local optima; the
        // band only guards against gross quality regressions
        let (ds, dp) = (serial.distortion(), par.distortion());
        if (dp - ds).abs() > 0.25 * ds.max(1e-9) + 1e-9 {
            return Err(format!("threads={threads}: distortion {dp} vs serial {ds}"));
        }
        Ok(())
    });
}

#[test]
fn threads_one_reproduces_serial_exactly() {
    // Bit-identical guarantee: the threads = 1 path is the historical
    // serial implementation (same RNG stream, same visit order, same
    // arithmetic) — labels and the entire history must match across runs
    // and across explicitly- vs default-constructed params.
    let data = gkmeans::data::synth::sift_like(1200, 17);
    let graph = brute::build(&data, 8, &Backend::native());
    let explicit = gk::GkMeansParams {
        kappa: 8,
        base: KmeansParams { max_iters: 6, threads: 1, ..Default::default() },
    };
    let defaulted = gk::GkMeansParams {
        kappa: 8,
        base: KmeansParams { max_iters: 6, ..Default::default() },
    };
    let a = gk::run_core(&data, 24, &graph, &explicit, &Backend::native());
    let b = gk::run_core(&data, 24, &graph, &defaulted, &Backend::native());
    assert_eq!(a.clustering.labels, b.clustering.labels);
    assert_eq!(a.history.len(), b.history.len());
    for (ha, hb) in a.history.iter().zip(&b.history) {
        assert_eq!(ha.moves, hb.moves, "iter {}", ha.iter);
        assert_eq!(
            ha.distortion.to_bits(),
            hb.distortion.to_bits(),
            "iter {} distortion not bit-identical",
            ha.iter
        );
    }
}

#[test]
fn parallel_runs_deterministic_per_thread_count() {
    let data = gkmeans::data::synth::sift_like(800, 23);
    let graph = brute::build(&data, 6, &Backend::native());
    let p = gk::GkMeansParams {
        kappa: 6,
        base: KmeansParams { max_iters: 5, threads: 3, ..Default::default() },
    };
    let a = gk::run_core(&data, 16, &graph, &p, &Backend::native());
    let b = gk::run_core(&data, 16, &graph, &p, &Backend::native());
    assert_eq!(a.clustering.labels, b.clustering.labels);
}

#[test]
fn parallel_brute_graph_is_bit_identical_at_scale() {
    let data = gkmeans::data::synth::sift_like(1500, 31);
    let serial = brute::build(&data, 10, &Backend::native());
    let par = brute::build_threaded(&data, 10, &Backend::native(), 4);
    for i in 0..data.rows() {
        assert_eq!(serial.neighbors(i), par.neighbors(i), "row {i}");
        assert_eq!(serial.distances(i), par.distances(i), "row {i}");
    }
}

#[test]
fn parallel_nn_descent_graph_quality_holds() {
    let data = gkmeans::data::synth::sift_like(900, 41);
    let exact = brute::build(&data, 1, &Backend::native());
    let serial = nn_descent::build(&data, 10, &nn_descent::NnDescentParams::default());
    let par = nn_descent::build(
        &data,
        10,
        &nn_descent::NnDescentParams { threads: 4, ..Default::default() },
    );
    par.check_invariants().unwrap();
    let rs = recall::recall_at_1(&serial, &exact);
    let rp = recall::recall_at_1(&par, &exact);
    assert!(rp >= rs - 0.1, "parallel recall {rp} far below serial {rs}");
}

#[test]
fn parallel_two_means_partitions_everything() {
    prop::check("parallel 2M-tree partition", 8, |g| {
        let n = g.usize_in(50, 500);
        let d = g.usize_in(2, 12);
        let k = g.usize_in(2, n.min(24));
        let threads = g.usize_in(2, 4);
        let data = g.matrix(n, d, 5.0);
        let params = TwoMeansParams { threads, ..Default::default() };
        let labels = two_means::run(&data, k, &params, &Backend::native());
        if labels.len() != n {
            return Err("label count".into());
        }
        let mut counts = vec![0usize; k];
        for &l in &labels {
            if l as usize >= k {
                return Err(format!("label {l} >= k {k}"));
            }
            counts[l as usize] += 1;
        }
        if counts.iter().any(|&c| c == 0) {
            return Err(format!("empty cluster: {counts:?}"));
        }
        Ok(())
    });
}

#[test]
fn end_to_end_pipeline_with_threads() {
    // The whole job path (Alg. 3 graph + Alg. 2 clustering) with the
    // threads knob set, as the CLI would run it.
    use gkmeans::coordinator::job::{ClusterJob, Method};
    use gkmeans::coordinator::pipeline;
    use gkmeans::data::DatasetSpec;
    let mut job = ClusterJob::new(
        DatasetSpec::Synth { kind: "sift".into(), n: 1000, seed: 3 },
        Method::GkMeans,
        20,
    );
    job.kappa = 8;
    job.tau = 3;
    job.xi = 30;
    job.base.max_iters = 5;
    job.base.threads = 4;
    let r = pipeline::run_job(&job, &Backend::native()).unwrap();
    assert!(r.distortion.is_finite() && r.distortion > 0.0);
    for w in r.history.windows(2) {
        assert!(
            w[1].distortion <= w[0].distortion + 1e-6 * (1.0 + w[0].distortion),
            "pipeline distortion rose: {} -> {}",
            w[0].distortion,
            w[1].distortion
        );
    }
}

#[test]
fn pool_auto_resolution_is_sane() {
    assert_eq!(pool::resolve_threads(3), 3);
    assert!(pool::resolve_threads(0) >= 1);
}

#[test]
fn parallel_closure_assignment_equivalent_to_serial() {
    // Closure k-means' assignment scan is sharded over the pool with
    // per-worker cursors; per-sample results are independent, so the
    // scan itself is bit-identical to serial (pinned at the unit level
    // inside kmeans::closure, where the factored assignment runs against
    // frozen state).  At the full-run level threads > 1 also
    // parallelizes the 2M-tree init (different split trees), so here the
    // guarantees are: deterministic per (seed, threads), valid, monotone
    // improvement, and final distortion within a band of serial.
    use gkmeans::kmeans::closure::{self, ClosureParams};
    let data = gkmeans::data::synth::sift_like(900, 67);
    let base = KmeansParams { max_iters: 6, ..Default::default() };
    let serial = closure::run_core(
        &data,
        12,
        &ClosureParams { base: base.clone(), ..Default::default() },
        &Backend::native(),
    );
    for threads in [2usize, 4] {
        let p = ClosureParams {
            base: KmeansParams { threads, ..base.clone() },
            ..Default::default()
        };
        let a = closure::run_core(&data, 12, &p, &Backend::native());
        let b = closure::run_core(&data, 12, &p, &Backend::native());
        assert_eq!(a.clustering.labels, b.clustering.labels, "threads={threads} not deterministic");
        a.clustering.check_invariants(&data).unwrap();
        let first = a.history.first().unwrap().distortion;
        let last = a.history.last().unwrap().distortion;
        assert!(last <= first + 1e-9, "threads={threads}: {first} -> {last}");
        let (ds, dp) = (serial.distortion(), a.distortion());
        assert!(
            (dp - ds).abs() <= 0.25 * ds.max(1e-9) + 1e-9,
            "threads={threads}: distortion {dp} too far from serial {ds}"
        );
    }
}

#[test]
fn gkmeans_batched_eval_threads_one_bit_stable() {
    // The batched Δℐ candidate evaluation must leave the threads = 1
    // path exactly where the seed left it: deterministic, and agreeing
    // with itself across runs to the distortion bit.  (The replica-based
    // bit-identity against the seed scalar loop lives next to the engine
    // in gkm::gkmeans, where the internals are reachable.)
    let data = gkmeans::data::synth::sift_like(1000, 29);
    let graph = brute::build(&data, 10, &Backend::native());
    let p = gk::GkMeansParams {
        kappa: 10,
        base: KmeansParams { max_iters: 6, ..Default::default() },
    };
    let a = gk::run_core(&data, 20, &graph, &p, &Backend::native());
    let b = gk::run_core(&data, 20, &graph, &p, &Backend::native());
    assert_eq!(a.clustering.labels, b.clustering.labels);
    for (ha, hb) in a.history.iter().zip(&b.history) {
        assert_eq!(ha.moves, hb.moves);
        assert_eq!(ha.distortion.to_bits(), hb.distortion.to_bits());
    }
}

#[test]
fn parallel_lloyd_assignment_is_bit_identical() {
    // Lloyd's assignment shards rows over workers; per-row results are
    // independent of sharding, so the whole run (labels, every history
    // entry) must be bit-identical at any thread count.
    let data = gkmeans::data::synth::sift_like(1100, 53);
    let params = KmeansParams { max_iters: 6, ..Default::default() };
    let serial = gkmeans::kmeans::lloyd::run_core(&data, 12, &params, &Backend::native());
    for threads in [2usize, 4] {
        let par = gkmeans::kmeans::lloyd::run_core(
            &data,
            12,
            &KmeansParams { threads, ..params.clone() },
            &Backend::native(),
        );
        assert_eq!(serial.clustering.labels, par.clustering.labels, "threads={threads}");
        assert_eq!(serial.history.len(), par.history.len());
        for (a, b) in serial.history.iter().zip(&par.history) {
            assert_eq!(a.moves, b.moves, "threads={threads} iter {}", a.iter);
            assert_eq!(
                a.distortion.to_bits(),
                b.distortion.to_bits(),
                "threads={threads} iter {} distortion not bit-identical",
                a.iter
            );
        }
    }
}

#[test]
fn parallel_minibatch_is_bit_identical() {
    // Mini-Batch's RNG stream is untouched by the sharded assignment, so
    // threads > 1 reproduces the serial run exactly.
    use gkmeans::kmeans::minibatch::{self, MiniBatchParams};
    let data = gkmeans::data::synth::sift_like(900, 59);
    let base = KmeansParams { max_iters: 12, ..Default::default() };
    let serial = minibatch::run_core(
        &data,
        10,
        &MiniBatchParams { batch: 128, base: base.clone() },
        &Backend::native(),
    );
    for threads in [2usize, 4] {
        let par = minibatch::run_core(
            &data,
            10,
            &MiniBatchParams { batch: 128, base: KmeansParams { threads, ..base.clone() } },
            &Backend::native(),
        );
        assert_eq!(serial.clustering.labels, par.clustering.labels, "threads={threads}");
        for (a, b) in serial.history.iter().zip(&par.history) {
            assert_eq!(
                a.distortion.to_bits(),
                b.distortion.to_bits(),
                "threads={threads} iter {}",
                a.iter
            );
        }
    }
}
