//! Cross-module integration tests: the full pipeline on each synthetic
//! dataset, method orderings the paper asserts, and config/CLI plumbing.

use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::graph::{brute, recall};
use gkmeans::kmeans::common::KmeansParams;
use gkmeans::runtime::Backend;

fn job(kind: &str, n: usize, method: Method, k: usize) -> ClusterJob {
    let mut j = ClusterJob::new(
        DatasetSpec::Synth { kind: kind.into(), n, seed: 11 },
        method,
        k,
    );
    j.kappa = 10;
    j.tau = 4;
    j.xi = 30;
    j.base.max_iters = 8;
    j
}

#[test]
fn pipeline_runs_on_all_four_dataset_standins() {
    let b = Backend::native();
    for kind in ["sift", "vlad", "glove", "gist"] {
        let n = if kind == "gist" { 400 } else { 800 };
        let r = pipeline::run_job(&job(kind, n, Method::GkMeans, 16), &b).unwrap();
        assert!(r.distortion.is_finite() && r.distortion > 0.0, "{kind}");
        assert_eq!(r.n, n);
    }
}

#[test]
fn gkmeans_is_faster_than_bkm_at_large_k() {
    // The paper's core claim, at integration-test scale: per-iteration
    // cost of GK-means is O(n·κ·d) vs BKM's O(n·k·d).  With k=100 ≫ κ=10
    // the iteration phase must be clearly faster.
    let b = Backend::native();
    let data = DatasetSpec::Synth { kind: "sift".into(), n: 4000, seed: 3 }
        .load()
        .unwrap();
    let mut gk = job("sift", 4000, Method::GkMeans, 100);
    gk.base.max_iters = 5;
    let mut bkm = job("sift", 4000, Method::Boost, 100);
    bkm.base.max_iters = 5;
    let rg = pipeline::run_job_on(&gk, &data, &b);
    let rb = pipeline::run_job_on(&bkm, &data, &b);
    assert!(
        rg.iter_seconds < rb.iter_seconds,
        "gk iter {}s !< bkm iter {}s",
        rg.iter_seconds,
        rb.iter_seconds
    );
    // and quality within a reasonable factor of BKM (paper: "drops very little")
    assert!(
        rg.distortion < rb.distortion * 1.25,
        "gk distortion {} vs bkm {}",
        rg.distortion,
        rb.distortion
    );
}

#[test]
fn quality_ordering_boost_beats_minibatch() {
    let b = Backend::native();
    let data = DatasetSpec::Synth { kind: "glove".into(), n: 2000, seed: 7 }
        .load()
        .unwrap();
    let rb = pipeline::run_job_on(&job("glove", 2000, Method::Boost, 40), &data, &b);
    let rm = pipeline::run_job_on(&job("glove", 2000, Method::MiniBatch, 40), &data, &b);
    assert!(
        rb.distortion <= rm.distortion * 1.001,
        "bkm {} vs minibatch {}",
        rb.distortion,
        rm.distortion
    );
}

#[test]
fn alg3_converges_like_fig2() {
    // Fig. 2's qualitative claim: within ~5 rounds, recall climbs well
    // above random and cell distortion drops substantially.
    let b = Backend::native();
    let data = DatasetSpec::Synth { kind: "sift".into(), n: 3000, seed: 9 }
        .load()
        .unwrap();
    let out = construct::build(
        &data,
        &ConstructParams { kappa: 10, xi: 50, tau: 5, seed: 1, threads: 1, ..Default::default() },
        &b,
    );
    let exact = brute::build(&data, 1, &b);
    let r = recall::recall_at_1(&out.graph, &exact);
    assert!(r > 0.5, "recall@1 after 5 rounds = {r}");
    let d0 = out.history.first().unwrap().distortion;
    let d4 = out.history.last().unwrap().distortion;
    assert!(d4 < d0 * 0.9, "distortion {d0} -> {d4}");
}

#[test]
fn graph_quality_improves_clustering_quality() {
    // Fig. 4's monotone trend: better graphs → lower final distortion.
    let b = Backend::native();
    let data = DatasetSpec::Synth { kind: "sift".into(), n: 2000, seed: 13 }
        .load()
        .unwrap();
    let base = KmeansParams { max_iters: 10, ..Default::default() };
    let params = gkmeans::gkm::gkmeans::GkMeansParams { kappa: 10, base };
    let mut dist_by_tau = Vec::new();
    for tau in [1usize, 6] {
        let g = construct::build(
            &data,
            &ConstructParams { kappa: 10, xi: 40, tau, seed: 1, threads: 1, ..Default::default() },
            &b,
        );
        let out = gkmeans::gkm::gkmeans::run_core(&data, 40, &g.graph, &params, &b);
        dist_by_tau.push(out.distortion());
    }
    assert!(
        dist_by_tau[1] <= dist_by_tau[0] * 1.02,
        "tau=6 ({}) should not be worse than tau=1 ({})",
        dist_by_tau[1],
        dist_by_tau[0]
    );
}

#[test]
fn dataset_file_roundtrip_through_pipeline() {
    // write a synthetic set to fvecs, reload via DatasetSpec::File
    let data = DatasetSpec::Synth { kind: "blobs".into(), n: 300, seed: 2 }
        .load()
        .unwrap();
    let path = std::env::temp_dir().join(format!("gkm_it_{}.fvecs", std::process::id()));
    gkmeans::data::io::write_fvecs(&path, &data).unwrap();
    let spec = DatasetSpec::parse(path.to_str().unwrap()).unwrap();
    let mut j = ClusterJob::new(spec, Method::Closure, 6);
    j.base.max_iters = 4;
    let r = pipeline::run_job(&j, &Backend::native()).unwrap();
    assert_eq!(r.n, 300);
    std::fs::remove_file(&path).ok();
}

#[test]
fn ann_on_constructed_graph_beats_random_guess() {
    let b = Backend::native();
    let data = DatasetSpec::Synth { kind: "sift".into(), n: 2000, seed: 17 }
        .load()
        .unwrap();
    let g = construct::build(
        &data,
        &ConstructParams { kappa: 10, xi: 40, tau: 6, seed: 3, threads: 1, ..Default::default() },
        &b,
    );
    let mut rng = gkmeans::util::rng::Rng::new(21);
    // sift_like(2000) has ~16 separated components and a pure KNN graph is
    // disconnected across them; enough entry points make a start in the
    // query's component near-certain ((15/16)^24 ≈ 0.2 miss).
    let sp = gkmeans::gkm::ann::SearchParams { ef: 32, entries: 24, seed: 1 };
    let mut hit = 0;
    let trials = 40;
    for _ in 0..trials {
        let qi = rng.below(2000);
        // perturbed self-query: true NN is qi itself
        let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.01).collect();
        let (res, _) = gkmeans::gkm::ann::search(&data, &g.graph, &q, 1, &sp, &mut rng);
        if res.first().map(|r| r.1 as usize) == Some(qi) {
            hit += 1;
        }
    }
    assert!(hit * 2 >= trials, "ANN hit rate {hit}/{trials}");
}
