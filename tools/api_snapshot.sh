#!/bin/sh
# Public-API snapshot: a simple `cargo public-api`-style textual dump of
# the `pub` item signatures under rust/src, committed as rust/api.txt and
# diffed in CI so public-surface changes are reviewed deliberately.
#
# Regenerate after an intentional surface change:
#   sh tools/api_snapshot.sh > rust/api.txt
#
# Notes: only the first line of multi-line signatures is captured, and
# `pub(crate)`/`pub(super)` items are excluded (they are not public API).
# That is deliberate — the goal is a cheap, deterministic diff target,
# not a full semantic API model.
set -eu
cd "$(dirname "$0")/.."

echo "# Public API snapshot - regenerate: sh tools/api_snapshot.sh > rust/api.txt"
find rust/src -name '*.rs' | LC_ALL=C sort | while read -r f; do
    rel="${f#rust/src/}"
    grep -hE '^[[:space:]]*pub (fn|struct|enum|trait|mod|use|const|type|static)' "$f" 2>/dev/null \
        | sed -E -e 's/^[[:space:]]+//' -e 's/ \{.*$//' -e 's/;[[:space:]]*$//' \
                 -e 's/[[:space:]]+/ /g' -e "s|^|${rel}: |" \
        || true
done
