"""Layer-2 correctness: model entry points vs oracles, shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(seed, m, d, scale=1.0):
    return (np.random.default_rng(seed).standard_normal((m, d)) * scale).astype(np.float32)


class TestAssignArgmin:
    def test_matches_ref(self):
        x, c = _rand(0, 256, 32), _rand(1, 256, 32)
        idx, dist = model.assign_argmin(x, c)
        ridx, rdist = ref.assign_argmin_ref(jnp.asarray(x), jnp.asarray(c))
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
        np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=1e-4, atol=1e-3)
        assert idx.dtype == jnp.int32

    def test_centroid_is_own_nn(self):
        c = _rand(2, 64, 16)
        idx, dist = model.assign_argmin(c, c)
        np.testing.assert_array_equal(np.asarray(idx), np.arange(64))
        np.testing.assert_allclose(np.asarray(dist), np.zeros(64), atol=1e-3)


class TestBisectAssign:
    def test_matches_ref(self):
        x = _rand(3, 256, 100)
        c2 = _rand(4, 2, 100)
        lab, margin = model.bisect_assign(x, c2)
        rlab, rmargin = ref.bisect_assign_ref(jnp.asarray(x), jnp.asarray(c2))
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(rlab))
        np.testing.assert_allclose(np.asarray(margin), np.asarray(rmargin), rtol=1e-3, atol=1e-2)

    def test_label_semantics(self):
        # Points exactly at c0 get label 0; at c1 get label 1.
        c2 = np.stack([np.zeros(8), np.ones(8) * 10]).astype(np.float32)
        x = np.concatenate([np.zeros((128, 8)), np.ones((128, 8)) * 10]).astype(np.float32)
        lab, margin = model.bisect_assign(x, c2)
        lab = np.asarray(lab)
        assert (lab[:128] == 0).all() and (lab[128:] == 1).all()
        m = np.asarray(margin)
        assert (m[:128] < 0).all() and (m[128:] > 0).all()


class TestCentroidUpdate:
    def test_matches_ref(self):
        x = _rand(5, 256, 32)
        labels = np.random.default_rng(6).integers(0, 256, 256).astype(np.int32)
        sums, counts = model.centroid_update(x, labels, 256)
        onehot = jnp.asarray(np.eye(256, dtype=np.float32)[labels])
        rsums, rcounts = ref.centroid_update_ref(jnp.asarray(x), onehot)
        np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-4, atol=1e-3)
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(rcounts))

    def test_mass_conservation(self):
        x = _rand(7, 256, 16)
        labels = np.random.default_rng(8).integers(0, 40, 256).astype(np.int32)
        sums, counts = model.centroid_update(x, labels, 256)
        np.testing.assert_allclose(
            np.asarray(sums).sum(axis=0), x.sum(axis=0), rtol=1e-4, atol=1e-2
        )
        assert np.asarray(counts).sum() == 256


@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([4, 32, 100]),
    k=st.integers(min_value=2, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_assign_consistent_with_update(d, k, seed):
    """Assignment + update invariants: every sum row r equals the sum of the
    x rows assigned to r (the Rust coordinator relies on this composite-
    vector identity for Delta-I bookkeeping)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((256, d)).astype(np.float32)
    c = rng.standard_normal((256, d)).astype(np.float32)
    idx, _ = model.assign_argmin(x, c)
    idx = np.asarray(idx)
    sums, counts = model.centroid_update(x, idx.astype(np.int32), 256)
    sums, counts = np.asarray(sums), np.asarray(counts)
    for r in np.unique(idx)[:5]:
        np.testing.assert_allclose(
            sums[r], x[idx == r].sum(axis=0), rtol=1e-4, atol=1e-2
        )
        assert counts[r] == (idx == r).sum()
