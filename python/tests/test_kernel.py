"""Layer-1 correctness: the Pallas pairwise-L2 kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the hot math: everything the Rust
runtime executes routes through this kernel.  Hypothesis sweeps shapes and
value regimes; fixed tests pin the known-tricky cases (identical rows,
zero vectors, large magnitudes, non-square tiles).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pairwise_l2 import pairwise_l2
from compile.kernels.ref import pairwise_l2_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, m, d, scale=1.0, dtype=np.float32):
    return (rng.standard_normal((m, d)) * scale).astype(dtype)


def assert_close(got, want, rtol=1e-4, atol=1e-3):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


class TestFixedCases:
    def test_small_exact(self):
        x = jnp.array([[0.0, 0.0], [3.0, 4.0]], dtype=jnp.float32)
        x = jnp.tile(x, (2, 1))  # 4 rows -> tile 4
        d = pairwise_l2(x, x, tile_m=4, tile_n=4)
        assert d.shape == (4, 4)
        assert_close(d[0, 1], 25.0)
        assert_close(jnp.diag(d), jnp.zeros(4))

    def test_identical_rows_nonnegative(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 64, 128, scale=100.0)
        d = pairwise_l2(x, x, tile_m=64, tile_n=64)
        assert np.all(np.asarray(d) >= 0.0), "cancellation produced negatives"
        # norms are ~1e6 here; f32 cancellation leaves a few units on the diag
        assert_close(np.diag(np.asarray(d)), np.zeros(64), atol=8.0)

    def test_zero_vectors(self):
        x = np.zeros((64, 32), np.float32)
        y = np.ones((64, 32), np.float32)
        d = pairwise_l2(x, y, tile_m=64, tile_n=64)
        assert_close(d, np.full((64, 64), 32.0))

    def test_rectangular_blocks(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, 256, 100)
        y = _rand(rng, 64, 100)
        d = pairwise_l2(x, y, tile_m=128, tile_n=64)
        assert d.shape == (256, 64)
        assert_close(d, pairwise_l2_ref(jnp.asarray(x), jnp.asarray(y)))

    def test_multi_tile_grid(self):
        rng = np.random.default_rng(2)
        x = _rand(rng, 256, 32)
        y = _rand(rng, 256, 32)
        d = pairwise_l2(x, y, tile_m=128, tile_n=128)
        assert_close(d, pairwise_l2_ref(jnp.asarray(x), jnp.asarray(y)))

    def test_sift_like_magnitudes(self):
        # SIFT components live in [0, 255]; distances get to ~1e6 -- check
        # the norm-expansion trick stays accurate there.
        rng = np.random.default_rng(3)
        x = (rng.random((128, 128)) * 255).astype(np.float32)
        d = pairwise_l2(x, x, tile_m=128, tile_n=128)
        # absolute distances reach ~2e6; f32 keeps ~7 significant digits
        assert_close(d, pairwise_l2_ref(jnp.asarray(x), jnp.asarray(x)), rtol=1e-3, atol=16.0)

    def test_dim_mismatch_raises(self):
        with pytest.raises(ValueError, match="dim mismatch"):
            pairwise_l2(np.zeros((4, 8), np.float32), np.zeros((4, 9), np.float32),
                        tile_m=4, tile_n=4)

    def test_indivisible_shape_raises(self):
        with pytest.raises(ValueError, match="not divisible"):
            pairwise_l2(np.zeros((5, 8), np.float32), np.zeros((4, 8), np.float32),
                        tile_m=4, tile_n=4)


@settings(max_examples=25, deadline=None)
@given(
    mlog=st.integers(min_value=2, max_value=7),
    nlog=st.integers(min_value=2, max_value=7),
    d=st.sampled_from([1, 3, 17, 32, 100, 128]),
    scale=st.sampled_from([1e-2, 1.0, 50.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_matches_ref(mlog, nlog, d, scale, seed):
    m, n = 2**mlog, 2**nlog
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, d, scale)
    y = _rand(rng, n, d, scale)
    got = pairwise_l2(x, y, tile_m=min(m, 128), tile_n=min(n, 128))
    want = pairwise_l2_ref(jnp.asarray(x), jnp.asarray(y))
    tol = max(1e-3, 1e-5 * scale * scale * d)
    assert_close(got, want, rtol=1e-4, atol=tol)
    assert np.all(np.asarray(got) >= 0.0)


@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([8, 64, 960]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_symmetry(d, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 64, d)
    got = np.asarray(pairwise_l2(x, x, tile_m=64, tile_n=64))
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-4)


def test_float64_inputs_are_cast():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((32, 16))  # f64
    d = pairwise_l2(x, x, tile_m=32, tile_n=32)
    assert d.dtype == jnp.float32
