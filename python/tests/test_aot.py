"""AOT pipeline sanity: lowering produces parseable HLO text + manifest.

The full Rust-side round trip (load text -> PJRT compile -> execute ->
numbers match) is covered by `cargo test` in rust/tests/pjrt_roundtrip.rs;
here we check the Python half is well-formed and deterministic.
"""

import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rows = aot.build(str(out), dims=(32,), verbose=False)
    return str(out), rows


def test_manifest_rows(built):
    out, rows = built
    names = {r[0] for r in rows}
    assert names == {
        "block_l2",
        "block_l2_small",
        "assign_argmin",
        "bisect_assign",
        "centroid_update",
    }
    for name, d, bm, bn, nout, fname, sha in rows:
        assert d == 32
        assert os.path.exists(os.path.join(out, fname))
        assert nout in (1, 2)


def test_hlo_text_shape_signatures(built):
    out, rows = built
    text = open(os.path.join(out, "block_l2_d32.hlo.txt")).read()
    assert "HloModule" in text
    assert "f32[256,32]" in text           # both params
    assert "f32[256,256]" in text          # output block
    small = open(os.path.join(out, "block_l2_small_d32.hlo.txt")).read()
    assert "f32[64,32]" in small and "f32[64,64]" in small


def test_entry_root_is_tuple(built):
    """return_tuple=True: the Rust loader unwraps to_tuple{1,2}()."""
    out, _ = built
    for f in ("block_l2_d32", "assign_argmin_d32"):
        text = open(os.path.join(out, f + ".hlo.txt")).read()
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        assert root_lines and any("tuple" in l or "(f32" in l or "(s32" in l
                                  for l in root_lines)


def test_lowering_is_deterministic(built):
    out, rows = built
    rows2 = aot.build(out, dims=(32,), verbose=False)
    assert [(r[0], r[6]) for r in rows] == [(r[0], r[6]) for r in rows2]


def test_manifest_file_format(built):
    out, rows = built
    lines = open(os.path.join(out, "manifest.tsv")).read().strip().splitlines()
    assert lines[0].startswith("#")
    assert len(lines) == len(rows) + 1
    for line in lines[1:]:
        cols = line.split("\t")
        assert len(cols) == 7
        int(cols[1]), int(cols[2]), int(cols[3]), int(cols[4])
