"""Layer-2 JAX compute graphs for the GK-means runtime.

Each public function here is an AOT entry point: a pure JAX function over
fixed block shapes, calling the Layer-1 Pallas kernel for the dense
distance math, lowered once by ``aot.py`` to an HLO-text artifact that the
Rust runtime loads via PJRT.  Python never runs at serving/clustering time.

Entry points (shapes are *fixed* per artifact; the Rust side pads partial
blocks and masks results):

  block_l2         (bm x d, bn x d) -> bm x bn squared-L2 matrix
  assign_argmin    (bm x d, bn x d) -> (argmin index (i32), min sq-dist)
  bisect_assign    (bm x d, 2 x d)  -> (label {0,1}, margin d0 - d1)
  centroid_update  (bm x d, bm i32 labels) -> (k x d sums, k counts)

All of them route the distance computation through
``kernels.pairwise_l2.pairwise_l2`` so the Pallas kernel is the single
source of truth for the hot math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.pairwise_l2 import pairwise_l2

__all__ = ["block_l2", "assign_argmin", "bisect_assign", "centroid_update"]


def _tile_for(m: int) -> int:
    """Largest power-of-two tile <= m, capped at 128."""
    t = 1
    while t * 2 <= m and t * 2 <= 128:
        t *= 2
    return t


def block_l2(x: jax.Array, y: jax.Array) -> tuple[jax.Array]:
    """Full squared-L2 distance block via the Pallas kernel."""
    tm = _tile_for(x.shape[0])
    tn = _tile_for(y.shape[0])
    return (pairwise_l2(x, y, tile_m=tm, tile_n=tn),)


def assign_argmin(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Closest-centroid assignment over one block of centroids.

    The Rust caller tiles over all k centroids in bn-sized blocks and
    reduces (index, dist) pairs across blocks; this entry handles one block.
    """
    (d,) = block_l2(x, c)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.min(d, axis=1)


def bisect_assign(x: jax.Array, c2: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Two-means bisection step for Alg. 1.

    Returns the 0/1 label per row and the signed margin d(x,c0) - d(x,c1);
    the equal-size adjustment sorts on the margin, so both come back.
    c2 arrives padded to the block width; only rows 0 and 1 are real.
    """
    (d,) = block_l2(x, c2)
    margin = d[:, 0] - d[:, 1]
    return (margin > 0).astype(jnp.int32), margin


def centroid_update(x: jax.Array, labels: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Composite vectors D_r = sum_{x_i in S_r} x_i and counts n_r.

    One-hot + matmul keeps the reduction on the MXU path instead of a
    scatter (scatters lower poorly on both TPU and XLA-CPU).
    """
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)  # (bm, k)
    sums = jax.lax.dot_general(
        onehot, x, dimension_numbers=(((0,), (0,)), ((), ()))
    )  # (k, d)
    counts = jnp.sum(onehot, axis=0)
    return sums, counts
