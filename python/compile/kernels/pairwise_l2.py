"""Layer-1 Pallas kernel: tiled pairwise squared-L2 distance block.

This is the compute hot-spot of GK-means and of every baseline it is
compared against: given a block of samples ``X`` (bm x d) and a block of
"others" ``Y`` (bn x d) -- centroids for assignment, cell members for KNN
refinement -- produce the full ``bm x bn`` matrix of squared Euclidean
distances::

    D[i, j] = ||x_i - y_j||^2 = ||x_i||^2 + ||y_j||^2 - 2 <x_i, y_j>

The kernel is written for the MXU systolic array: the cross term is a single
``dot_general`` over a (TM x d) x (d x TN) tile pair, and the two norm terms
are rank-1 broadcasts fused around it.  Tile sizes are chosen so a tile pair
plus the output tile fit comfortably in VMEM (see DESIGN.md section Perf):
for TM = TN = 128 and d <= 960 the footprint is

    (TM*d + TN*d + TM*TN) * 4 B  <=  (128*960*2 + 128*128) * 4 B ~= 1.0 MB,

far under the ~16 MB VMEM budget, leaving room for double buffering.

On this CPU-only environment the kernel MUST be lowered with
``interpret=True`` (real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute).  Interpret mode lowers to plain HLO
``dot``/``broadcast`` ops, which XLA-CPU fuses into an efficient GEMM -- so
the same artifact is the CPU hot path here and an MXU kernel on real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_l2", "DEFAULT_TILE"]

DEFAULT_TILE = 128


def _pairwise_l2_kernel(x_ref, y_ref, o_ref):
    """One (TM x d) x (TN x d) tile: squared-L2 distances into (TM x TN).

    ``x_ref``/``y_ref`` hold full rows of the tile (the d axis is not
    blocked: d <= 960 keeps a full row-tile in VMEM, and keeping the
    contraction un-blocked means a single MXU pass with no accumulator
    carry).
    """
    x = x_ref[...]
    y = y_ref[...]
    # Cross term on the MXU: (TM x d) . (d x TN). Accumulate in f32.
    cross = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xsq = jnp.sum(x * x, axis=1, keepdims=True)  # (TM, 1)
    ysq = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, TN)
    # max(0, .) guards the tiny negative values produced by cancellation
    # when x_i == y_j; downstream top-k / argmin code relies on d >= 0.
    o_ref[...] = jnp.maximum(xsq + ysq - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def pairwise_l2(
    x: jax.Array,
    y: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Full (m x n) squared-L2 distance matrix via the Pallas tile kernel.

    Both ``m`` and ``n`` must be multiples of the respective tile size (the
    AOT entry points use fixed padded block shapes; padding/masking is the
    caller's job -- in production, the Rust runtime's).
    """
    m, d = x.shape
    n, d2 = y.shape
    if d != d2:
        raise ValueError(f"dim mismatch: {d} vs {d2}")
    if m % tile_m or n % tile_n:
        raise ValueError(f"shape ({m},{n}) not divisible by tile ({tile_m},{tile_n})")

    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        _pairwise_l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
