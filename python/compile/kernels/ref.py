"""Pure-jnp oracles for the Pallas kernels and the L2 model entry points.

Everything in here is deliberately naive: these functions define *what* the
kernels must compute, with no tiling, no tricks.  pytest checks the Pallas /
model outputs against these to tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(m x d), (n x d) -> (m x n) squared Euclidean distances."""
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def assign_argmin_ref(x: jnp.ndarray, c: jnp.ndarray):
    """Closest-centroid assignment: returns (indices (m,), sq-dists (m,))."""
    d = pairwise_l2_ref(x, c)
    idx = jnp.argmin(d, axis=1)
    return idx.astype(jnp.int32), jnp.min(d, axis=1)


def bisect_assign_ref(x: jnp.ndarray, c2: jnp.ndarray):
    """Two-means bisection step: labels in {0,1} and the signed margin.

    margin = d(x, c0) - d(x, c1); label = margin > 0 (i.e. closer to c1...
    label 1 means x is on c1's side).  The margin is what the equal-size
    adjustment sorts on (Alg. 1 step 9).
    """
    d = pairwise_l2_ref(x, c2)
    margin = d[:, 0] - d[:, 1]
    return (margin > 0).astype(jnp.int32), margin


def centroid_update_ref(x: jnp.ndarray, onehot: jnp.ndarray):
    """Cluster composite vectors and counts from a one-hot assignment.

    x: (m x d), onehot: (m x k) -> (sums (k x d), counts (k,)).
    """
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0)
    return sums, counts
