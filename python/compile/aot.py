"""AOT lowering: JAX/Pallas entry points -> HLO *text* artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits, per data dimension D in ``DIMS``:

    block_l2_d{D}.hlo.txt          (256 x D, 256 x D) -> 256 x 256
    block_l2_small_d{D}.hlo.txt    ( 64 x D,  64 x D) ->  64 x 64
    assign_argmin_d{D}.hlo.txt     (256 x D, 256 x D) -> (i32 256, f32 256)
    bisect_assign_d{D}.hlo.txt     (256 x D,   2 x D) -> (i32 256, f32 256)
    centroid_update_d{D}.hlo.txt   (256 x D, i32 256) -> (256 x D, 256)

plus ``manifest.tsv`` (entry<TAB>dim<TAB>bm<TAB>bn<TAB>outputs<TAB>file) that
the Rust runtime reads to discover artifacts.

HLO **text** is the interchange format, NOT ``lowered.compile().serialize()``
or the HloModuleProto bytes: jax >= 0.5 emits protos with 64-bit instruction
ids that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Data dimensions we pre-compile for: test/quickstart (32), GloVe (100),
# SIFT (128), VLAD (512), GIST (960).
DIMS = (32, 100, 128, 512, 960)
BM = 256  # large block: assignment / bisection tiles
BS = 64   # small block: within-cell KNN refinement (cell size xi ~= 50)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entries_for_dim(d: int):
    """(name, fn, example-arg specs, #outputs) for one data dimension."""
    return [
        ("block_l2", model.block_l2, (_spec((BM, d)), _spec((BM, d))), 1),
        ("block_l2_small", model.block_l2, (_spec((BS, d)), _spec((BS, d))), 1),
        ("assign_argmin", model.assign_argmin, (_spec((BM, d)), _spec((BM, d))), 2),
        ("bisect_assign", model.bisect_assign, (_spec((BM, d)), _spec((2, d))), 2),
        (
            "centroid_update",
            lambda x, l: model.centroid_update(x, l, BM),
            (_spec((BM, d)), _spec((BM,), jnp.int32)),
            2,
        ),
    ]


def build(out_dir: str, dims=DIMS, verbose: bool = True) -> list[tuple]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for d in dims:
        for name, fn, specs, nout in entries_for_dim(d):
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}_d{d}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            bm = specs[0].shape[0]
            bn = specs[1].shape[0] if len(specs[1].shape) == 2 else 0
            digest = hashlib.sha256(text.encode()).hexdigest()[:12]
            rows.append((name, d, bm, bn, nout, fname, digest))
            if verbose:
                print(f"  {fname:36s} {len(text):>9d} chars  sha={digest}")
    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# entry\tdim\tbm\tbn\toutputs\tfile\tsha256_12\n")
        for r in rows:
            f.write("\t".join(str(v) for v in r) + "\n")
    if verbose:
        print(f"wrote {len(rows)} artifacts + {manifest}")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--dims",
        default=",".join(str(d) for d in DIMS),
        help="comma-separated data dimensions to compile for",
    )
    args = ap.parse_args(argv)
    dims = tuple(int(t) for t in args.dims.split(",") if t)
    build(args.out_dir, dims)
    return 0


if __name__ == "__main__":
    sys.exit(main())
