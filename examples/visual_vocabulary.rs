//! End-to-end driver: visual-vocabulary construction — the workload the
//! paper's introduction motivates (large-scale image retrieval needs 10⁴–
//! 10⁶ visual words from SIFT descriptors, and k-means is the bottleneck).
//!
//! Pipeline, all three layers composing:
//!   1. dataset: SIFT-like descriptors (synthetic stand-in; drop a real
//!      `.fvecs` path in `--data` to use SIFT1M);
//!   2. GK-means builds its KNN graph (Alg. 3) and clusters into k visual
//!      words (Alg. 2) — bulk distance math running through the
//!      AOT-compiled Pallas kernel on PJRT when artifacts exist;
//!   3. baselines (BKM, Lloyd, closure, Mini-Batch) on the same data;
//!   4. report: per-method time/distortion, the GK-means speed-up factors,
//!      and a quantization demo (assigning unseen descriptors to words).
//!
//! This run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example visual_vocabulary -- [--n 30000] [--k 300]
//! ```

use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::eval::report::{f, Table};
use gkmeans::runtime::Backend;
use gkmeans::util::cli;

fn main() {
    let args = cli::parse_env(&["n", "k", "data", "iters"]);
    let n = args.usize_or("n", 30_000);
    let k = args.usize_or("k", 300);
    let iters = args.usize_or("iters", 20);
    let backend = Backend::auto();

    let spec = match args.get("data") {
        Some(path) => DatasetSpec::File { path: path.into() },
        None => DatasetSpec::Synth { kind: "sift".into(), n, seed: 20170707 },
    };
    let data = spec.load().expect("dataset");
    println!(
        "visual vocabulary: n={} d={} -> k={k} words (backend={})",
        data.rows(),
        data.dim(),
        backend.name()
    );

    let mut table = Table::new(&["method", "init_s", "iter_s", "total_s", "distortion", "speedup_vs_bkm"]);
    let mut results = Vec::new();
    for &m in &[Method::GkMeans, Method::Closure, Method::MiniBatch, Method::Boost, Method::Lloyd] {
        let mut job = ClusterJob::new(spec.clone(), m, k);
        job.kappa = 30;
        job.tau = 8;
        job.base.max_iters = iters;
        let r = pipeline::run_job_on(&job, &data, &backend);
        println!(
            "  {:<18} total={:>7.2}s  E={:.2}",
            m.name(),
            r.total_seconds,
            r.distortion
        );
        results.push(r);
    }
    let bkm_total = results
        .iter()
        .find(|r| r.method == Method::Boost)
        .map(|r| r.total_seconds)
        .unwrap_or(f64::NAN);
    for r in &results {
        table.row(&[
            r.method.name().into(),
            f(r.init_seconds),
            f(r.iter_seconds),
            f(r.total_seconds),
            f(r.distortion),
            format!("{:.1}x", bkm_total / r.total_seconds),
        ]);
    }
    println!("\n{}", table.render());

    // --- quantization demo: assign 1000 unseen descriptors to words ---
    // fit the actual vocabulary as a model artifact, then predict
    // out-of-sample — the model owns the centroids and the assignment path
    use gkmeans::model::{Clusterer, GkMeans, RunContext};
    let ctx = RunContext::new(&backend).max_iters(iters);
    let vocab = GkMeans::new(k).kappa(30).fit(&data, &ctx);
    let unseen = gkmeans::data::synth::sift_like(1_000, 777);
    let timer = gkmeans::util::timer::Timer::start();
    // predict_on keeps the quantization on the selected backend
    let words = vocab.predict_on(&unseen, &backend);
    let q_secs = timer.elapsed_s();
    let used: std::collections::HashSet<u32> = words.iter().copied().collect();
    println!(
        "quantized 1000 unseen descriptors in {:.1} ms ({} distinct words used)",
        q_secs * 1e3,
        used.len()
    );
    table
        .write_csv(&gkmeans::eval::report::results_dir().join("visual_vocabulary.csv"))
        .ok();
}
