//! Clustering word embeddings (the paper's GloVe1M scenario): group a
//! vocabulary of embedding vectors into semantic-ish clusters, the
//! weak-structure regime where graph quality is hardest to build.
//!
//! Demonstrates: per-dataset behaviour differences (GloVe-like data has
//! overlapping clusters → higher distortion, lower graph recall than
//! SIFT-like), and the library's reporting utilities.
//!
//! ```bash
//! cargo run --release --example text_embeddings -- [--n 20000] [--k 200]
//! ```

use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::runtime::Backend;
use gkmeans::util::cli;

fn main() {
    let args = cli::parse_env(&["n", "k"]);
    let n = args.usize_or("n", 20_000);
    let k = args.usize_or("k", 200);
    let backend = Backend::auto();
    let spec = DatasetSpec::Synth { kind: "glove".into(), n, seed: 20170707 };
    let data = spec.load().unwrap();
    println!("word-embedding clustering: n={n} d={} k={k}", data.dim());

    // GK-means with recall measurement: GloVe-like data is the paper's
    // hardest graph case, so watch the recall number.
    let mut job = ClusterJob::new(spec.clone(), Method::GkMeans, k);
    job.kappa = 30;
    job.tau = 10;
    job.base.max_iters = 20;
    job.measure_recall = n <= 20_000;
    let r = pipeline::run_job_on(&job, &data, &backend);
    println!(
        "GK-means: total={:.2}s distortion={:.4} graph-recall@1={}",
        r.total_seconds,
        r.distortion,
        r.recall.map(|x| format!("{x:.3}")).unwrap_or_else(|| "n/a".into())
    );

    // convergence curve (Fig. 5c analogue)
    println!("\ndistortion curve:");
    for h in r.history.iter().step_by(2) {
        println!(
            "  iter {:>2}  t={:>7.2}s  E={:.4}  moves={}",
            h.iter, h.seconds, h.distortion, h.moves
        );
    }

    // cluster-size distribution: embeddings cluster unevenly
    let mut jb = ClusterJob::new(spec, Method::Boost, k);
    jb.base.max_iters = 20;
    let rb = pipeline::run_job_on(&jb, &data, &backend);
    println!(
        "\nBKM reference: total={:.2}s distortion={:.4} (GK-means gap: {:+.2}%)",
        rb.total_seconds,
        rb.distortion,
        (r.distortion / rb.distortion - 1.0) * 100.0
    );
}
