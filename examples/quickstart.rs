//! Quickstart: cluster a synthetic dataset with GK-means in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::gkm::{self, gkmeans::GkMeansParams};
use gkmeans::runtime::Backend;

fn main() {
    // 10K 32-d points with blob structure.
    let data = blobs(&BlobSpec::quick(10_000, 32, 64), 42);

    // PJRT-compiled Pallas kernels when `make artifacts` has run; the
    // native mirror otherwise.
    let backend = Backend::auto();

    // GK-means end to end: Alg. 3 builds the KNN graph, Alg. 2 clusters
    // with it. κ = 20 neighbors consulted per sample.
    let params = GkMeansParams { kappa: 20, ..Default::default() };
    let out = gkm::cluster(&data, 100, &params, &backend);

    println!("clustered n={} into k=100 on backend={}", data.rows(), backend.name());
    println!("distortion      = {:.4}", out.distortion());
    println!("total time      = {:.2}s (init {:.2}s)", out.total_seconds, out.init_seconds);
    println!("epochs run      = {}", out.history.len() - 1);
    let sizes: Vec<u32> = out.clustering.counts.clone();
    println!(
        "cluster sizes   = min {} / median {} / max {}",
        sizes.iter().min().unwrap(),
        {
            let mut s = sizes.clone();
            s.sort_unstable();
            s[s.len() / 2]
        },
        sizes.iter().max().unwrap()
    );
}
