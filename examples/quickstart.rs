//! Quickstart: fit GK-means, keep the model, query it — in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gkmeans::prelude::*;

fn main() {
    // 10K 32-d points with blob structure.
    let data = blobs(&BlobSpec::quick(10_000, 32, 64), 42);

    // PJRT-compiled Pallas kernels when `make artifacts` has run; the
    // native mirror otherwise.
    let backend = Backend::auto();

    // GK-means end to end through the fit -> model API: Alg. 3 builds the
    // KNN graph, Alg. 2 clusters with it. κ = 20 neighbors per sample.
    let ctx = RunContext::new(&backend);
    let model = GkMeans::new(100).kappa(20).fit(&data, &ctx);

    println!("clustered n={} into k={} on backend={}", data.rows(), model.k, backend.name());
    println!("distortion      = {:.4}", model.distortion());
    println!(
        "total time      = {:.2}s (graph {:.2}s, init {:.2}s)",
        model.total_seconds, model.graph_seconds, model.init_seconds
    );
    println!("epochs run      = {}", model.history.len() - 1);

    let mut sizes = vec![0u32; model.k];
    for &l in &model.labels {
        sizes[l as usize] += 1;
    }
    let mut sorted = sizes.clone();
    sorted.sort_unstable();
    println!(
        "cluster sizes   = min {} / median {} / max {}",
        sorted[0],
        sorted[sorted.len() / 2],
        sorted[sorted.len() - 1]
    );

    // The model is an artifact: assign vectors it has never seen.
    let unseen = blobs(&BlobSpec::quick(500, 32, 64), 43);
    let labels = model.predict(&unseen);
    println!("predicted       = {} out-of-sample assignments", labels.len());
}
