//! ANN serving, production shape: a thin *client* of `gkm-serve`
//! (§4.3's application behind a real network front door).
//!
//! The first run fits GK-means (Alg. 3 graph + Alg. 2 clustering,
//! vectors embedded) and saves the `FittedModel` artifact; every later
//! run reuses it — no re-indexing on startup.  Serving itself lives in
//! the `gkm-serve` binary (micro-batching, sharding, metrics); this
//! example just bootstraps an artifact, talks the wire protocol, and
//! summarizes what the service did.
//!
//! ```bash
//! # self-hosted: bootstrap an artifact, start an in-process server,
//! # drive mixed predict/search traffic against it over TCP
//! cargo run --release --example ann_service -- [--n 20000] [--queries 500] [--ef 64]
//! # force a refit of the artifact:
//! cargo run --release --example ann_service -- --refit
//! # against an already-running `gkm-serve MODEL.gkm`:
//! cargo run --release --example ann_service -- --addr 127.0.0.1:7070
//! ```

use std::path::PathBuf;
use std::time::{Duration, Instant};

use gkmeans::data::synth;
use gkmeans::model::{Clusterer, FittedModel, GkMeans, RunContext};
use gkmeans::runtime::Backend;
use gkmeans::serve::proto::{stats_value, Client};
use gkmeans::serve::{ServeConfig, Server, ShardedIndex};
use gkmeans::util::cli;
use gkmeans::util::rng::Rng;

fn main() {
    let args = cli::parse_env(&["n", "queries", "ef", "kappa", "tau", "index", "addr", "clients"]);
    let n = args.usize_or("n", 20_000);
    let nq = args.usize_or("queries", 500);
    let ef = args.usize_or("ef", 64);
    let kappa = args.usize_or("kappa", 20);
    let tau = args.usize_or("tau", 16);
    let clients = args.usize_or("clients", 4);

    // --- resolve a serving endpoint ---------------------------------
    // --addr: talk to an external gkm-serve.  Otherwise bootstrap an
    // artifact (fit + save on the first run, load after) and self-host
    // an in-process `serve::Server` — the same code path the binary runs.
    let mut _local: Option<gkmeans::serve::ServerHandle> = None;
    let (addr, dim) = match args.get("addr") {
        Some(a) => {
            let addr: std::net::SocketAddr = a.parse().expect("--addr host:port");
            // dim is discovered by probing: a deliberately wrong-sized
            // predict comes back as "query dim X != index dim D"
            let mut probe = Client::connect(addr).expect("connect");
            probe.ping().expect("ping");
            let err = probe.predict(&[0.0]).expect_err("1-d probe should mismatch");
            let dim: usize = err
                .rsplit(' ')
                .next()
                .and_then(|s| s.parse().ok())
                .expect("server names its dim in the mismatch error");
            println!("using external server at {addr} (dim {dim})");
            (addr, dim)
        }
        None => {
            let index_path: PathBuf = args.get("index").map(PathBuf::from).unwrap_or_else(|| {
                std::env::temp_dir().join(format!("ann_service_n{n}_kappa{kappa}_tau{tau}.gkm"))
            });
            let model = if index_path.exists() && !args.flag("refit") {
                let t = Instant::now();
                let m = FittedModel::load(&index_path).expect("loading saved index");
                println!(
                    "loaded index {} in {:.3}s (n={}, kappa={}, fitted by {})",
                    index_path.display(),
                    t.elapsed().as_secs_f64(),
                    m.n_train,
                    m.graph.as_ref().map(|g| g.kappa()).unwrap_or(0),
                    m.method.name()
                );
                m
            } else {
                println!("indexing: n={n} SIFT-like descriptors, kappa={kappa}, tau={tau}");
                let data = synth::sift_like(n, 20170707);
                let backend = Backend::auto();
                let ctx = RunContext::new(&backend).seed(1).keep_data(true).max_iters(5);
                let m = GkMeans::new((n / 50).max(2)).kappa(kappa).tau(tau).fit(&data, &ctx);
                println!(
                    "fitted in {:.2}s (graph {:.2}s); saving {}",
                    m.total_seconds,
                    m.graph_seconds,
                    index_path.display()
                );
                m.save(&index_path).expect("saving index");
                m
            };
            let dim = model.dim;
            let backing = match &model.data {
                Some(d) if d.is_resident() => "resident",
                Some(_) => "paged from disk",
                None => panic!("index must embed its vectors (keep_data)"),
            };
            println!("vectors: {backing} ({} x {dim})", model.n_train);
            let index = ShardedIndex::new(vec![model]).expect("index");
            let cfg = ServeConfig {
                default_ef: ef,
                batch_window: Duration::from_micros(200),
                max_batch: 64,
                ..ServeConfig::default()
            };
            let handle = Server::start(index, &cfg).expect("start server");
            let addr = handle.addr();
            println!("self-hosted gkm-serve listening on {addr}");
            _local = Some(handle);
            (addr, dim)
        }
    };

    // --- drive mixed predict/search traffic over the wire -----------
    // every 5th request is a predict; `clients` connections run
    // concurrently so the server's micro-batcher has queries to coalesce
    let per_client = (nq / clients.max(1)).max(1);
    println!("driving {clients} clients x {per_client} requests (top-10, ef={ef})...");
    let t0 = Instant::now();
    let lat_groups: Vec<Vec<(bool, u64)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|tid| {
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    let mut rng = Rng::new(99 + tid as u64);
                    let mut out = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let q: Vec<f32> = (0..dim).map(|_| 30.0 * rng.normal()).collect();
                        let t = Instant::now();
                        let is_search = i % 5 != 0;
                        if is_search {
                            c.search(&q, 10, ef).expect("search");
                        } else {
                            c.predict(&q).expect("predict");
                        }
                        out.push((is_search, t.elapsed().as_micros() as u64));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut lats: Vec<u64> = lat_groups.iter().flatten().map(|&(_, us)| us).collect();
    let searches = lat_groups.iter().flatten().filter(|&&(is_s, _)| is_s).count();
    let total = lats.len();
    lats.sort_unstable();
    let mean = lats.iter().sum::<u64>() as f64 / total as f64;
    println!("served {total} requests ({searches} searches) in {wall:.2}s:");
    println!(
        "  latency: mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms",
        mean / 1e3,
        lats[total / 2] as f64 / 1e3,
        lats[(total * 99 / 100).min(total - 1)] as f64 / 1e3
    );
    println!("  throughput: {:.0} requests/s across {clients} clients", total as f64 / wall);

    // --- what the service saw, from its own metrics ------------------
    let mut c = Client::connect(addr).expect("connect for stats");
    let stats = c.stats().expect("stats");
    println!("server-side STATS:");
    for key in ["requests", "qps", "lat_p50_us", "lat_p99_us", "batch_mean", "cache_hit_rate"] {
        if let Some(v) = stats_value(&stats, key) {
            println!("  {key} = {v}");
        }
    }
    if let Some(handle) = _local.take() {
        handle.shutdown();
        println!("self-hosted server drained cleanly");
    }
}
