//! ANN search service: build the Alg. 3 graph once, then serve nearest-
//! neighbor queries from it (§4.3's application of the KNN graph).
//!
//! Reports per-query latency and recall against exact search — the
//! serving-side numbers behind the paper's "<3 ms per query at recall
//! >0.9" claim (at their 100M scale; this runs the same pipeline at a
//! laptop scale).
//!
//! ```bash
//! cargo run --release --example ann_service -- [--n 20000] [--queries 500] [--ef 64]
//! ```

use gkmeans::data::synth;
use gkmeans::gkm::ann::{self, SearchParams};
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::runtime::Backend;
use gkmeans::util::cli;
use gkmeans::util::rng::Rng;
use gkmeans::util::timer::Timer;

fn main() {
    let args = cli::parse_env(&["n", "queries", "ef", "kappa", "tau"]);
    let n = args.usize_or("n", 20_000);
    let nq = args.usize_or("queries", 500);
    let ef = args.usize_or("ef", 64);
    let kappa = args.usize_or("kappa", 20);
    let tau = args.usize_or("tau", 16);
    let backend = Backend::auto();

    println!("indexing: n={n} SIFT-like descriptors, kappa={kappa}, tau={tau}");
    let data = synth::sift_like(n, 20170707);
    let build = construct::build(
        &data,
        &ConstructParams { kappa, xi: 50, tau, seed: 1, threads: 1 },
        &backend,
    );
    println!("graph built in {:.2}s", build.total_seconds);

    // serve queries
    let mut rng = Rng::new(99);
    let sp = SearchParams { ef, entries: 48, seed: 5 };
    let mut latencies = Vec::with_capacity(nq);
    let mut hits = 0usize;
    for _ in 0..nq {
        let qi = rng.below(n);
        let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.5 * rng.normal()).collect();
        // exact answer for recall accounting
        let mut best = f32::INFINITY;
        let mut want = 0u32;
        for j in 0..n {
            let dd = gkmeans::core_ops::dist::d2(&q, data.row(j));
            if dd < best {
                best = dd;
                want = j as u32;
            }
        }
        let t = Timer::start();
        let (res, _) = ann::search(&data, &build.graph, &q, 10, &sp, &mut rng);
        latencies.push(t.elapsed_s());
        if res.first().map(|r| r.1) == Some(want) {
            hits += 1;
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = latencies.iter().sum::<f64>() / nq as f64;
    println!("served {nq} queries (top-10, ef={ef}):");
    println!("  recall@1 = {:.3}", hits as f64 / nq as f64);
    println!(
        "  latency: mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms",
        mean * 1e3,
        latencies[nq / 2] * 1e3,
        latencies[(nq * 99 / 100).min(nq - 1)] * 1e3
    );
    println!(
        "  throughput: {:.0} queries/s (single thread)",
        1.0 / mean
    );
}
