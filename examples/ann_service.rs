//! ANN search service over a *saved model artifact* (§4.3's application,
//! production shape): the first run fits GK-means (Alg. 3 graph + Alg. 2
//! clustering, vectors embedded) and saves the `FittedModel`; every later
//! run loads the artifact and serves immediately — no re-indexing on
//! startup, which is the whole point of the fit → model → query surface.
//!
//! Reports per-query latency and recall against exact search — the
//! serving-side numbers behind the paper's "<3 ms per query at recall
//! >0.9" claim (at their 100M scale; this runs the same pipeline at a
//! laptop scale).
//!
//! ```bash
//! cargo run --release --example ann_service -- [--n 20000] [--queries 500] [--ef 64]
//! # second invocation loads the saved index:
//! cargo run --release --example ann_service
//! # force a refit:
//! cargo run --release --example ann_service -- --refit
//! ```

use std::path::PathBuf;

use gkmeans::data::synth;
use gkmeans::gkm::ann::SearchParams;
use gkmeans::model::{Clusterer, FittedModel, GkMeans, RunContext};
use gkmeans::runtime::Backend;
use gkmeans::util::cli;
use gkmeans::util::rng::Rng;
use gkmeans::util::timer::Timer;

fn main() {
    let args = cli::parse_env(&["n", "queries", "ef", "kappa", "tau", "index"]);
    let n = args.usize_or("n", 20_000);
    let nq = args.usize_or("queries", 500);
    let ef = args.usize_or("ef", 64);
    let kappa = args.usize_or("kappa", 20);
    let tau = args.usize_or("tau", 16);
    let index: PathBuf = args.get("index").map(PathBuf::from).unwrap_or_else(|| {
        std::env::temp_dir().join(format!("ann_service_n{n}_kappa{kappa}_tau{tau}.gkm"))
    });
    let backend = Backend::auto();

    // --- load the artifact, or fit + save it on the first run ---
    let model = if index.exists() && !args.flag("refit") {
        let t = Timer::start();
        let m = FittedModel::load(&index).expect("loading saved index");
        println!(
            "loaded index {} in {:.3}s (n={}, kappa={}, fitted by {})",
            index.display(),
            t.elapsed_s(),
            m.n_train,
            m.graph.as_ref().map(|g| g.kappa()).unwrap_or(0),
            m.method.name()
        );
        m
    } else {
        println!("indexing: n={n} SIFT-like descriptors, kappa={kappa}, tau={tau}");
        let data = synth::sift_like(n, 20170707);
        let ctx = RunContext::new(&backend).seed(1).keep_data(true).max_iters(5);
        let m = GkMeans::new((n / 50).max(2)).kappa(kappa).tau(tau).fit(&data, &ctx);
        println!(
            "fitted in {:.2}s (graph {:.2}s); saving {}",
            m.total_seconds,
            m.graph_seconds,
            index.display()
        );
        m.save(&index).expect("saving index");
        m
    };
    let data = model.data.as_ref().expect("index embeds its vectors");
    println!(
        "vectors: {} ({} x {})",
        if data.is_resident() { "resident" } else { "paged from disk" },
        data.rows(),
        data.dim()
    );

    // --- serve queries from the artifact ---
    // (one cursor for exact-recall accounting; the model's own search
    // path opens its own cursors internally)
    use gkmeans::data::store::VecStore as _;
    let mut cur = data.open();
    let mut rng = Rng::new(99);
    let sp = SearchParams { ef, entries: 48, seed: 5 };
    let mut latencies = Vec::with_capacity(nq);
    let mut hits = 0usize;
    for _ in 0..nq {
        let qi = rng.below(data.rows());
        let q: Vec<f32> = cur.row(qi).iter().map(|v| v + 0.5 * rng.normal()).collect();
        // exact answer for recall accounting
        let mut best = f32::INFINITY;
        let mut want = 0u32;
        for j in 0..data.rows() {
            let dd = gkmeans::core_ops::dist::d2(&q, cur.row(j));
            if dd < best {
                best = dd;
                want = j as u32;
            }
        }
        let t = Timer::start();
        let res = model.search(&q, 10, &sp).expect("graph + vectors present");
        latencies.push(t.elapsed_s());
        if res.first().map(|r| r.1) == Some(want) {
            hits += 1;
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = latencies.iter().sum::<f64>() / nq as f64;
    println!("served {nq} queries (top-10, ef={ef}):");
    println!("  recall@1 = {:.3}", hits as f64 / nq as f64);
    println!(
        "  latency: mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms",
        mean * 1e3,
        latencies[nq / 2] * 1e3,
        latencies[(nq * 99 / 100).min(nq - 1)] * 1e3
    );
    println!(
        "  throughput: {:.0} queries/s (single thread)",
        1.0 / mean
    );
}
